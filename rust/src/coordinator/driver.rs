//! The m-Cubes iteration driver (Algorithm 2) as a resumable state
//! machine.
//!
//! The stepping logic lives in [`SessionCore`], a backend-agnostic
//! state machine that advances exactly one iteration per `step` call
//! over a [`RunPlan`]'s stages. Everything else is a thin loop over it:
//!
//! * [`drive`] runs a fixed-layout backend (PJRT artifacts, raw
//!   `EngineBackend`s) to completion, firing observers each iteration.
//! * `api::Session` (the public resumable handle) owns the integrand
//!   and rebuilds native backends at stage boundaries, so plans may
//!   change the per-iteration call budget or sampling strategy
//!   mid-run; it also exports/restores [`api::Checkpoint`]s.
//! * [`integrate_native_core`] — the shared core behind the facade and
//!   the scheduler — is `Session` plus an observer loop.
//!
//! Every run ends with a typed [`StopReason`] carried on
//! [`DriveOutcome`] and the final [`IterationEvent`].

// The iteration-counter narrowing cast below is audited by
// `cargo xtask lint` (MC001); see docs/invariants.md.
#![allow(clippy::cast_possible_truncation)]

use super::backend::VSampleBackend;
use crate::api::{
    Checkpoint, GridState, IterationEvent, ObserverControl, RunPlan, Session, StopReason,
};
use crate::error::{Error, Result};
use crate::engine::ExecPath;
use crate::estimator::{Convergence, EstimatorState, WeightedEstimator};
use crate::grid::{Bins, GridMode};
use crate::integrands::IntegrandRef;
use crate::strat::{AllocStats, Sampling};
use crate::util::threadpool::default_threads;
use std::time::Instant;

/// Everything the driver needs to know about one integration job.
///
/// `#[non_exhaustive]`: construct via [`JobConfig::default`] and
/// mutate fields (or use the `api::Integrator` builder) — future knobs
/// will not be breaking changes.
#[derive(Debug, Clone)]
#[non_exhaustive]
pub struct JobConfig {
    /// Evaluation budget per iteration (Algorithm 2 `maxcalls`).
    /// Stages may override it per stage (native engine only).
    pub maxcalls: usize,
    /// Importance bins per axis.
    pub nb: usize,
    /// Grid programs / thread groups (must match artifact for PJRT).
    pub nblocks: usize,
    /// Target relative error.
    pub tau_rel: f64,
    /// The iteration schedule. [`RunPlan::classic`] reproduces the
    /// seed's flat `itmax`/`ita`/`skip` triple bitwise and is the
    /// default (`classic(15, 10, 2)`).
    pub plan: RunPlan,
    /// Optional cap on total integrand evaluations: the run stops with
    /// [`StopReason::TargetCallsReached`] at the end of the first
    /// iteration that reaches it. `None` (default) leaves the plan as
    /// the only budget.
    pub max_total_calls: Option<usize>,
    /// Reset the estimator when chi2/dof blows past the convergence
    /// guard during the adjust phase (recovers from a bad warm-up).
    pub reset_on_inconsistency: bool,
    /// RNG seed.
    pub seed: u32,
    /// Grid mode: PerAxis (m-Cubes) or Shared1D (m-Cubes1D).
    pub grid_mode: GridMode,
    /// Per-cube sample allocation: uniform m-Cubes (`Sampling::Uniform`)
    /// or VEGAS+ adaptive stratification (`Sampling::VegasPlus`).
    /// Native engine only — the PJRT artifacts compile the uniform
    /// layout. Stages may override it per stage.
    pub sampling: Sampling,
    /// Worker threads for the native engine.
    pub threads: usize,
    /// Native-engine execution schedule: the fused streaming tile loop
    /// (default) or the historical whole-block pipeline. Bitwise
    /// identical either way (property-tested) — a performance knob,
    /// never a results knob, so it is not part of the checkpoint.
    pub exec: ExecPath,
    /// Shard workers the native engine splits each iteration across
    /// (`1`, the default, runs the ordinary single-worker backends).
    /// Like `threads`/`exec` this is an execution knob, never a results
    /// knob — the N-shard merge is bitwise equal to the single-worker
    /// run — so it is excluded from the manifest digest.
    pub shards: usize,
    /// Spool directory for sharded runs: when set (and `shards > 1`)
    /// the sharded backend scatters sealed task files there and gathers
    /// reports written by external `mcubes shard-worker` processes,
    /// falling back to in-process recompute for stragglers. `None`
    /// (default) keeps the shard pool in-process.
    pub shard_dir: Option<String>,
}

impl Default for JobConfig {
    fn default() -> Self {
        JobConfig {
            maxcalls: 1 << 17,
            nb: 50,
            nblocks: 8,
            tau_rel: 1e-3,
            plan: RunPlan::default(),
            max_total_calls: None,
            reset_on_inconsistency: true,
            seed: 42,
            grid_mode: GridMode::PerAxis,
            sampling: Sampling::Uniform,
            threads: default_threads(),
            exec: ExecPath::default(),
            shards: 1,
            shard_dir: None,
        }
    }
}

impl JobConfig {
    /// Chainable setter (the struct is `#[non_exhaustive]`, so
    /// downstream code configures via `Default` + these setters or the
    /// `api::Integrator` builder).
    pub fn with_maxcalls(mut self, maxcalls: usize) -> Self {
        self.maxcalls = maxcalls;
        self
    }

    /// Chainable setter for the importance-bin count.
    pub fn with_bins(mut self, nb: usize) -> Self {
        self.nb = nb;
        self
    }

    /// Chainable setter for the block count.
    pub fn with_blocks(mut self, nblocks: usize) -> Self {
        self.nblocks = nblocks;
        self
    }

    /// Chainable setter for the target relative error.
    pub fn with_tolerance(mut self, tau_rel: f64) -> Self {
        self.tau_rel = tau_rel;
        self
    }

    /// Chainable setter for the iteration schedule.
    pub fn with_plan(mut self, plan: RunPlan) -> Self {
        self.plan = plan;
        self
    }

    /// Chainable setter for the total-call budget.
    pub fn with_call_budget(mut self, max_total_calls: usize) -> Self {
        self.max_total_calls = Some(max_total_calls);
        self
    }

    /// Chainable setter for the RNG seed.
    pub fn with_seed(mut self, seed: u32) -> Self {
        self.seed = seed;
        self
    }

    /// Chainable setter for the grid mode.
    pub fn with_grid_mode(mut self, grid_mode: GridMode) -> Self {
        self.grid_mode = grid_mode;
        self
    }

    /// Chainable setter for the sampling strategy.
    pub fn with_sampling(mut self, sampling: Sampling) -> Self {
        self.sampling = sampling;
        self
    }

    /// Chainable setter for the native-engine worker-thread count.
    pub fn with_threads(mut self, threads: usize) -> Self {
        self.threads = threads;
        self
    }

    /// Chainable setter for the native-engine execution schedule.
    pub fn with_exec(mut self, exec: ExecPath) -> Self {
        self.exec = exec;
        self
    }

    /// Chainable setter for the shard-worker count.
    pub fn with_shards(mut self, shards: usize) -> Self {
        self.shards = shards;
        self
    }

    /// Chainable setter for the shard spool directory (implies the
    /// process transport when `shards > 1`).
    pub fn with_shard_dir(mut self, dir: impl Into<String>) -> Self {
        self.shard_dir = Some(dir.into());
        self
    }

    pub fn validate(&self) -> Result<()> {
        if self.maxcalls < 4 {
            return Err(Error::Config(format!(
                "maxcalls must be >= 4 (the layout needs at least 2 samples \
                 in at least 1 cube), got {}",
                self.maxcalls
            )));
        }
        if self.nb < 2 {
            return Err(Error::Config(format!(
                "nb (importance bins per axis) must be >= 2, got {}",
                self.nb
            )));
        }
        if self.nblocks == 0 {
            return Err(Error::Config(
                "nblocks (grid programs) must be >= 1, got 0".into(),
            ));
        }
        if !(self.tau_rel > 0.0) {
            return Err(Error::Config("tau_rel must be > 0".into()));
        }
        if self.max_total_calls == Some(0) {
            return Err(Error::Config(
                "max_total_calls must be >= 1 (use None for unlimited)".into(),
            ));
        }
        if self.shards == 0 {
            return Err(Error::Config(
                "shards must be >= 1 (1 means single-worker), got 0".into(),
            ));
        }
        self.sampling.validate()?;
        self.plan.validate()?;
        Ok(())
    }

    /// Convergence policy derived from this config.
    pub fn convergence(&self) -> Convergence {
        Convergence::with_tau(self.tau_rel)
    }
}

/// Final result of an integration job.
#[derive(Debug, Clone)]
pub struct IntegrationOutput {
    pub integral: f64,
    pub sigma: f64,
    pub chi2_dof: f64,
    pub rel_err: f64,
    pub iterations: usize,
    pub converged: bool,
    /// Total integrand evaluations consumed.
    pub calls_used: usize,
    /// Wall time of the whole job (seconds).
    pub total_time: f64,
    /// Time inside backend.run — the paper's "kernel time" (seconds).
    pub kernel_time: f64,
    /// Backend label.
    pub backend: &'static str,
}

/// `drive` result: the integration output, the adapted grid (ready to
/// warm-start a later run), and the typed reason the run ended.
///
/// `#[non_exhaustive]`: constructed only inside the crate.
#[derive(Debug, Clone)]
#[non_exhaustive]
pub struct DriveOutcome {
    pub output: IntegrationOutput,
    pub grid: GridState,
    /// Why the run ended.
    pub stop: StopReason,
}

/// A [`RunPlan`] stage with its inherited fields resolved against the
/// owning [`JobConfig`].
#[derive(Debug, Clone)]
pub(crate) struct ResolvedStage {
    pub(crate) iters: usize,
    pub(crate) calls: usize,
    pub(crate) adapt: bool,
    pub(crate) discard: bool,
    pub(crate) sampling: Sampling,
    pub(crate) label: String,
}

/// Everything one `SessionCore::step` produced — the owned raw
/// material for both `api::Iteration` and [`IterationEvent`].
#[derive(Debug, Clone)]
pub(crate) struct StepRecord {
    pub(crate) index: usize,
    pub(crate) stage: usize,
    pub(crate) adapting: bool,
    pub(crate) discarded: bool,
    pub(crate) estimate: crate::estimator::IterationResult,
    pub(crate) integral: f64,
    pub(crate) sigma: f64,
    pub(crate) chi2_dof: f64,
    pub(crate) rel_err: f64,
    pub(crate) calls_used: usize,
    pub(crate) estimator_reset: bool,
    pub(crate) alloc: Option<AllocStats>,
    /// The step finished its stage and the cursor moved to the next
    /// one — backend-owning callers rebuild their backend now.
    pub(crate) stage_changed: bool,
    pub(crate) stop: Option<StopReason>,
}

/// The backend-agnostic m-Cubes iteration state machine: plan cursor,
/// importance grid, weighted estimator, and stop bookkeeping. One
/// `step` call advances exactly one iteration on whatever backend the
/// caller hands in (the caller owns backend lifecycle, so fixed-layout
/// drives and stage-switching sessions share this core).
pub(crate) struct SessionCore {
    stages: Vec<ResolvedStage>,
    bins: Bins,
    est: WeightedEstimator,
    conv: Convergence,
    stage_idx: usize,
    stage_iter: usize,
    iteration: usize,
    calls_used: usize,
    kernel_time: f64,
    stop: Option<StopReason>,
}

impl SessionCore {
    /// Fresh core for `cfg` over a `(d, nb)` grid, optionally seeded
    /// with a warm-start grid (shape- and mode-checked).
    pub(crate) fn new(
        cfg: &JobConfig,
        d: usize,
        nb: usize,
        warm: Option<&GridState>,
    ) -> Result<SessionCore> {
        cfg.validate()?;
        let bins = match warm {
            Some(gs) => {
                gs.compatible(d, nb)?;
                if gs.mode() != cfg.grid_mode {
                    return Err(Error::Config(format!(
                        "warm-start grid mode {:?} != configured grid mode {:?}; \
                         adapt the donor in the same mode (or match grid_mode to \
                         the donor)",
                        gs.mode(),
                        cfg.grid_mode
                    )));
                }
                gs.bins().clone()
            }
            None => Bins::uniform_mode(d, nb, cfg.grid_mode),
        };
        let stages = cfg
            .plan
            .stages()
            .iter()
            .map(|s| ResolvedStage {
                iters: s.iters,
                calls: s.calls.unwrap_or(cfg.maxcalls),
                adapt: s.adapt,
                discard: s.discard,
                sampling: s.sampling.unwrap_or(cfg.sampling),
                label: s.label(),
            })
            .collect();
        Ok(SessionCore {
            stages,
            bins,
            est: WeightedEstimator::new(),
            conv: cfg.convergence(),
            stage_idx: 0,
            stage_iter: 0,
            iteration: 0,
            calls_used: 0,
            kernel_time: 0.0,
            stop: None,
        })
    }

    /// Rebuild a core from checkpoint state. The cursor must be
    /// internally consistent (`iteration` equals the iterations the
    /// completed stages plus `stage_iter` account for).
    #[allow(clippy::too_many_arguments)]
    pub(crate) fn restore(
        cfg: &JobConfig,
        d: usize,
        nb: usize,
        grid: &GridState,
        est: EstimatorState,
        stage_idx: usize,
        stage_iter: usize,
        iteration: usize,
        calls_used: usize,
        stop: Option<StopReason>,
    ) -> Result<SessionCore> {
        est.validate()?;
        let mut core = SessionCore::new(cfg, d, nb, Some(grid))?;
        if stage_idx > core.stages.len() {
            return Err(Error::Config(format!(
                "checkpoint stage {} out of range for a {}-stage plan",
                stage_idx,
                core.stages.len()
            )));
        }
        if stage_idx < core.stages.len() && stage_iter >= core.stages[stage_idx].iters {
            return Err(Error::Config(format!(
                "checkpoint stage-iteration {} out of range for stage {} \
                 ({} iterations)",
                stage_iter, stage_idx, core.stages[stage_idx].iters
            )));
        }
        let done: usize = core.stages[..stage_idx].iter().map(|s| s.iters).sum();
        if iteration != done + stage_iter {
            return Err(Error::Config(format!(
                "checkpoint cursor inconsistent: iteration {iteration} != \
                 {done} completed-stage iterations + stage_iter {stage_iter}"
            )));
        }
        core.est = WeightedEstimator::from_state(est);
        core.stage_idx = stage_idx;
        core.stage_iter = stage_iter;
        core.iteration = iteration;
        core.calls_used = calls_used;
        // A checkpoint of a finished run restores finished (never
        // silently un-finish a converged/aborted session); one taken
        // past the last stage is exhausted even without a recorded
        // stop (pre-stop checkpoint files).
        core.stop = stop;
        if core.stop.is_none() && stage_idx >= core.stages.len() {
            core.stop = Some(StopReason::Exhausted);
        }
        Ok(core)
    }

    pub(crate) fn stages(&self) -> &[ResolvedStage] {
        &self.stages
    }

    pub(crate) fn stage_idx(&self) -> usize {
        self.stage_idx
    }

    pub(crate) fn stage_iter(&self) -> usize {
        self.stage_iter
    }

    pub(crate) fn iteration(&self) -> usize {
        self.iteration
    }

    pub(crate) fn calls_used(&self) -> usize {
        self.calls_used
    }

    pub(crate) fn bins(&self) -> &Bins {
        &self.bins
    }

    pub(crate) fn estimator(&self) -> &WeightedEstimator {
        &self.est
    }

    pub(crate) fn estimator_state(&self) -> EstimatorState {
        self.est.state()
    }

    pub(crate) fn stop(&self) -> Option<StopReason> {
        self.stop
    }

    pub(crate) fn finished(&self) -> bool {
        self.stop.is_some()
    }

    /// End the run after the current iteration on the observer's
    /// behalf (no-op when another stop reason already fired).
    pub(crate) fn abort(&mut self) {
        if self.stop.is_none() {
            self.stop = Some(StopReason::ObserverAbort);
        }
    }

    /// Advance exactly one iteration on `backend`. The caller
    /// guarantees `backend` matches the current stage's layout and
    /// sampling; `step` must not be called once `finished()`.
    pub(crate) fn step(
        &mut self,
        backend: &mut dyn VSampleBackend,
        cfg: &JobConfig,
    ) -> Result<StepRecord> {
        debug_assert!(self.stop.is_none(), "stepping a finished session");
        let stage_idx = self.stage_idx;
        let stage = &self.stages[stage_idx];
        let t0 = Instant::now();
        // lint:allow(MC001, iteration index — bounded far below 2^32 by RunPlan validation (per-stage iters sum); the Philox counter word and PJRT kernel ABI are u32)
        let (r, contrib) = backend.run(&self.bins, cfg.seed, self.iteration as u32, stage.adapt)?;
        self.kernel_time += t0.elapsed().as_secs_f64();
        self.calls_used += backend.layout().calls();
        let index = self.iteration;
        self.iteration += 1;
        self.stage_iter += 1;

        if !stage.discard {
            self.est.push(r);
        }

        // Grid refinement happens before the stop decision so a
        // converged final iteration still leaves an adapted grid behind.
        let mut estimator_reset = false;
        if stage.adapt {
            if let Some(c) = contrib {
                self.bins.adjust(&c);
            }
            if cfg.reset_on_inconsistency
                && self.est.iterations() >= 2
                && self.est.chi2_dof() > self.conv.max_chi2_dof
            {
                // Importance grid was still moving: drop the stale
                // estimates, keep the (better) grid.
                self.est.reset();
                estimator_reset = true;
            }
        }

        let stage_changed = if self.stage_iter >= stage.iters {
            self.stage_idx += 1;
            self.stage_iter = 0;
            true
        } else {
            false
        };

        if self.conv.satisfied(&self.est) {
            self.stop = Some(StopReason::Converged);
        } else if cfg
            .max_total_calls
            .is_some_and(|target| self.calls_used >= target)
        {
            self.stop = Some(StopReason::TargetCallsReached);
        } else if self.stage_idx >= self.stages.len() {
            self.stop = Some(StopReason::Exhausted);
        }

        Ok(StepRecord {
            index,
            stage: stage_idx,
            adapting: self.stages[stage_idx].adapt,
            discarded: self.stages[stage_idx].discard,
            estimate: r,
            integral: self.est.integral(),
            sigma: self.est.sigma(),
            chi2_dof: self.est.chi2_dof(),
            rel_err: self.est.rel_err(),
            calls_used: self.calls_used,
            estimator_reset,
            alloc: backend.alloc_stats(),
            stage_changed: stage_changed && self.stop.is_none(),
            stop: self.stop,
        })
    }

    /// Observer event for a step record (borrows the live grid).
    pub(crate) fn event<'s>(&'s self, rec: &StepRecord) -> IterationEvent<'s> {
        IterationEvent {
            iteration: rec.index,
            stage: rec.stage,
            stage_label: &self.stages[rec.stage].label,
            adjusting: rec.adapting,
            discarded: rec.discarded,
            estimate: rec.estimate,
            integral: rec.integral,
            sigma: rec.sigma,
            chi2_dof: rec.chi2_dof,
            rel_err: rec.rel_err,
            calls_used: rec.calls_used,
            estimator_reset: rec.estimator_reset,
            converged: rec.stop == Some(StopReason::Converged),
            stop: rec.stop,
            alloc: rec.alloc,
            grid: &self.bins,
        }
    }

    /// Assemble the final output (the run must be finished).
    pub(crate) fn into_outcome(
        self,
        backend_name: &'static str,
        strat: Option<crate::api::StratSnapshot>,
        total_time: f64,
    ) -> DriveOutcome {
        let stop = self.stop.unwrap_or(StopReason::Exhausted);
        let output = IntegrationOutput {
            integral: self.est.integral(),
            sigma: self.est.sigma(),
            chi2_dof: self.est.chi2_dof(),
            rel_err: self.est.rel_err(),
            iterations: self.iteration,
            converged: stop == StopReason::Converged,
            calls_used: self.calls_used,
            total_time,
            kernel_time: self.kernel_time,
            backend: backend_name,
        };
        let mut grid = GridState::from_bins(self.bins);
        if let Some(s) = strat {
            grid = grid.with_strat(s);
        }
        DriveOutcome {
            output,
            grid,
            stop,
        }
    }
}

/// Run the two-phase m-Cubes loop on any fixed-layout backend — a thin
/// observer loop over [`SessionCore`].
///
/// * `warm_start` — adapted grid from a previous run. Must match the
///   backend layout's `(d, nb)` and `cfg.grid_mode` — a mismatch is a
///   config error, never a silent override. `None` starts from a
///   uniform grid.
/// * `observer` — called once per iteration with an
///   [`IterationEvent`] after grid adjustment and the stop decision;
///   returning [`ObserverControl::Abort`] ends the run with
///   [`StopReason::ObserverAbort`].
///
/// Because the backend's layout is fixed, plans with per-stage
/// `calls`/`sampling` overrides are rejected here — use
/// `api::Session` (native engine) for those.
pub fn drive(
    backend: &mut dyn VSampleBackend,
    cfg: &JobConfig,
    warm_start: Option<&GridState>,
    mut observer: Option<&mut dyn FnMut(&IterationEvent) -> ObserverControl>,
) -> Result<DriveOutcome> {
    cfg.validate()?;
    for (i, stage) in cfg.plan.stages().iter().enumerate() {
        let calls_override = stage.calls.is_some_and(|c| c != cfg.maxcalls);
        let sampling_override = stage.sampling.is_some_and(|s| s != cfg.sampling);
        if calls_override || sampling_override {
            return Err(Error::Config(format!(
                "run plan stage {i} overrides the per-stage calls/sampling, \
                 but this backend's layout is fixed — per-stage overrides \
                 require the native-engine session (`api::Session` / \
                 `api::Integrator`)"
            )));
        }
    }
    let layout = backend.layout();
    let mut core = SessionCore::new(cfg, layout.d, layout.nb, warm_start)?;
    let t_start = Instant::now();
    while !core.finished() {
        let rec = core.step(backend, cfg)?;
        if let Some(cb) = observer.as_mut() {
            if cb(&core.event(&rec)) == ObserverControl::Abort {
                core.abort();
            }
        }
    }
    let strat = backend.strat_export();
    Ok(core.into_outcome(backend.name(), strat, t_start.elapsed().as_secs_f64()))
}

/// Native-engine drive over an integrand handle — the shared core the
/// facade, the scheduler, and the deprecated shims all call. Builds an
/// `api::Session` (which dispatches per stage between the uniform
/// m-Cubes engine and the VEGAS+ stratified path) and drains it,
/// firing observers.
pub(crate) fn integrate_native_core(
    f: &IntegrandRef,
    cfg: &JobConfig,
    warm_start: Option<&GridState>,
    mut observer: Option<&mut dyn FnMut(&IterationEvent) -> ObserverControl>,
) -> Result<DriveOutcome> {
    let mut session = match warm_start {
        Some(grid) => Session::resume(
            f.clone(),
            cfg.clone(),
            &Checkpoint::from_grid(grid.clone()),
        )?,
        None => Session::new(f.clone(), cfg.clone())?,
    };
    while let Some(iteration) = session.step()? {
        if let Some(cb) = observer.as_mut() {
            if cb(&session.event(&iteration)) == ObserverControl::Abort {
                session.abort();
            }
        }
    }
    session.finish()
}

/// Escalating-precision native integration: runs the driver at
/// increasing call budgets (x`factor` per level) until `tau_rel` is
/// met, genuinely carrying the adapted grid across levels — the
/// strategy behind the paper's high-precision runs (Fig. 1/2).
/// Iteration indices in observer events are cumulative across levels.
/// A `max_total_calls` budget spans all levels.
pub(crate) fn escalate_native(
    f: &IntegrandRef,
    base: &JobConfig,
    max_escalations: usize,
    factor: usize,
    warm_start: Option<&GridState>,
    mut observer: Option<&mut dyn FnMut(&IterationEvent) -> ObserverControl>,
) -> Result<DriveOutcome> {
    if factor < 2 {
        return Err(Error::Config(format!(
            "escalation factor must be >= 2, got {factor}"
        )));
    }
    let mut cfg = base.clone();
    let mut grid: Option<GridState> = warm_start.cloned();
    let mut last: Option<DriveOutcome> = None;
    let mut total_time = 0.0;
    let mut kernel_time = 0.0;
    let mut calls_used = 0;
    let mut iterations = 0;
    for level in 0..=max_escalations {
        if let Some(target) = base.max_total_calls {
            if calls_used >= target {
                break;
            }
            // The budget spans levels: hand each level the remainder.
            cfg.max_total_calls = Some(target - calls_used);
        }
        let outcome = {
            let base_it = iterations;
            match observer.as_deref_mut() {
                Some(cb) => {
                    let mut shifted = |ev: &IterationEvent| {
                        cb(&IterationEvent {
                            iteration: base_it + ev.iteration,
                            ..*ev
                        })
                    };
                    integrate_native_core(f, &cfg, grid.as_ref(), Some(&mut shifted))?
                }
                None => integrate_native_core(f, &cfg, grid.as_ref(), None)?,
            }
        };
        total_time += outcome.output.total_time;
        kernel_time += outcome.output.kernel_time;
        calls_used += outcome.output.calls_used;
        iterations += outcome.output.iterations;
        let stop = outcome.stop;
        grid = Some(outcome.grid.clone());
        last = Some(DriveOutcome {
            output: IntegrationOutput {
                total_time,
                kernel_time,
                calls_used,
                iterations,
                ..outcome.output
            },
            grid: outcome.grid,
            stop,
        });
        // Escalate only past an exhausted plan; a converged run is
        // done, and an abort or spent call budget must be honored.
        if stop != StopReason::Exhausted {
            break;
        }
        if level < max_escalations {
            cfg.maxcalls *= factor;
            // Fresh seed per level so escalations resample.
            cfg.seed = cfg.seed.wrapping_add(0x9E37_79B9);
        }
    }
    last.ok_or_else(|| Error::Config("no escalation levels ran".into()))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::integrands::by_name;
    use crate::strat::Layout;

    fn cfg(calls: usize, tau: f64) -> JobConfig {
        JobConfig {
            maxcalls: calls,
            nb: 50,
            tau_rel: tau,
            plan: RunPlan::classic(15, 10, 2),
            seed: 11,
            threads: 4,
            ..Default::default()
        }
    }

    fn with_plan(mut c: JobConfig, itmax: usize, ita: usize, skip: usize) -> JobConfig {
        c.plan = RunPlan::classic(itmax, ita, skip);
        c
    }

    fn integrate(f: &IntegrandRef, c: &JobConfig) -> Result<IntegrationOutput> {
        integrate_native_core(f, c, None, None).map(|o| o.output)
    }

    #[test]
    fn converges_on_smooth_integrands() {
        for (name, d, calls) in [("f5", 8, 1 << 15), ("f3", 3, 1 << 14), ("f2", 6, 1 << 15)] {
            let f = by_name(name, d).unwrap();
            let out = integrate(&f, &cfg(calls, 1e-3)).unwrap();
            assert!(out.converged, "{name} did not converge: {out:?}");
            let truth = f.true_value().unwrap();
            let rel = ((out.integral - truth) / truth).abs();
            // 1e-3 claimed; allow 5x for statistical slop across seeds.
            assert!(rel < 5e-3, "{name}: rel err {rel}, out {out:?}");
            assert!(out.chi2_dof < 5.0, "{name}: chi2 {}", out.chi2_dof);
        }
    }

    #[test]
    fn error_estimate_is_honest() {
        // |estimate - truth| should usually be within ~3 claimed sigmas.
        let f = by_name("f4", 5).unwrap();
        let out = integrate(&f, &cfg(1 << 15, 1e-3)).unwrap();
        let truth = f.true_value().unwrap();
        assert!(
            (out.integral - truth).abs() < 4.0 * out.sigma,
            "bias: {} vs sigma {}",
            (out.integral - truth).abs(),
            out.sigma
        );
    }

    #[test]
    fn two_phase_runs_na_iterations() {
        let f = by_name("f5", 4).unwrap();
        // unreachable tau: run all iters
        let c = with_plan(cfg(1 << 12, 1e-12), 6, 3, 0);
        let out = integrate(&f, &c).unwrap();
        assert!(!out.converged);
        assert_eq!(out.iterations, 6);
        assert_eq!(
            out.calls_used,
            6 * Layout::compute(4, 1 << 12, 50, 8).unwrap().calls()
        );
    }

    #[test]
    fn exhausted_and_converged_stop_reasons() {
        let f = by_name("f5", 4).unwrap();
        let c = with_plan(cfg(1 << 12, 1e-12), 4, 2, 0);
        let out = integrate_native_core(&f, &c, None, None).unwrap();
        assert_eq!(out.stop, StopReason::Exhausted);
        assert!(!out.output.converged);

        let c = cfg(1 << 14, 1e-3);
        let out = integrate_native_core(&f, &c, None, None).unwrap();
        assert_eq!(out.stop, StopReason::Converged);
        assert!(out.output.converged);
    }

    #[test]
    fn target_calls_budget_stops_the_run() {
        let f = by_name("f5", 4).unwrap();
        let mut c = with_plan(cfg(1 << 12, 1e-12), 10, 5, 0);
        let per_iter = Layout::compute(4, 1 << 12, 50, 8).unwrap().calls();
        c.max_total_calls = Some(3 * per_iter);
        let out = integrate_native_core(&f, &c, None, None).unwrap();
        assert_eq!(out.stop, StopReason::TargetCallsReached);
        assert_eq!(out.output.iterations, 3);
        assert_eq!(out.output.calls_used, 3 * per_iter);
        // A budget that lands mid-iteration still finishes it.
        c.max_total_calls = Some(3 * per_iter - 1);
        let out = integrate_native_core(&f, &c, None, None).unwrap();
        assert_eq!(out.output.iterations, 3);
    }

    #[test]
    fn validates_config() {
        let f = by_name("f4", 5).unwrap();
        // Discard-only classic schedule (skip >= itmax) is rejected.
        let c2 = with_plan(cfg(1 << 12, 1e-3), 10, 7, 20);
        let err = integrate(&f, &c2).unwrap_err().to_string();
        assert!(err.contains("discards every stage"), "{err}");
        // Empty plan (itmax 0) is rejected.
        let c3 = with_plan(cfg(1 << 12, 1e-3), 0, 0, 0);
        assert!(integrate(&f, &c3).is_err());
    }

    #[test]
    fn validate_rejects_zero_budget_and_shape() {
        assert!(JobConfig::default().validate().is_ok());

        let err = JobConfig::default()
            .with_maxcalls(0)
            .validate()
            .unwrap_err()
            .to_string();
        assert!(err.contains("maxcalls"), "{err}");
        assert!(JobConfig::default().with_maxcalls(3).validate().is_err());

        let err = JobConfig::default()
            .with_bins(0)
            .validate()
            .unwrap_err()
            .to_string();
        assert!(err.contains("nb"), "{err}");

        let err = JobConfig::default()
            .with_blocks(0)
            .validate()
            .unwrap_err()
            .to_string();
        assert!(err.contains("nblocks"), "{err}");

        let err = JobConfig::default()
            .with_call_budget(0)
            .validate()
            .unwrap_err()
            .to_string();
        assert!(err.contains("max_total_calls"), "{err}");
    }

    #[test]
    fn adaptive_escalates_until_converged() {
        let f = by_name("f4", 8).unwrap();
        let base = with_plan(cfg(1 << 12, 1e-3), 10, 8, 2);
        let out = escalate_native(&f, &base, 4, 4, None, None).unwrap().output;
        assert!(out.converged, "{out:?}");
        let truth = f.true_value().unwrap();
        let rel = ((out.integral - truth) / truth).abs();
        assert!(rel < 1e-2, "rel {rel}");
    }

    #[test]
    fn onedim_mode_works_on_symmetric() {
        let f = by_name("f4", 5).unwrap();
        let mut c = with_plan(cfg(1 << 15, 1e-3), 20, 10, 2);
        c.grid_mode = GridMode::Shared1D;
        let out = integrate(&f, &c).unwrap();
        assert!(out.converged, "{out:?}");
        let truth = f.true_value().unwrap();
        assert!(((out.integral - truth) / truth).abs() < 5e-3);
    }

    #[test]
    fn seed_reproducibility() {
        let f = by_name("f3", 3).unwrap();
        let a = integrate(&f, &cfg(1 << 13, 1e-3)).unwrap();
        let b = integrate(&f, &cfg(1 << 13, 1e-3)).unwrap();
        assert_eq!(a.integral, b.integral);
        assert_eq!(a.sigma, b.sigma);
    }

    #[test]
    fn observer_sees_every_iteration() {
        let f = by_name("f5", 4).unwrap();
        let c = with_plan(cfg(1 << 12, 1e-12), 5, 3, 0);
        let mut seen: Vec<(usize, bool, bool)> = Vec::new();
        let mut cb = |ev: &IterationEvent| {
            assert!(ev.grid.validate().is_ok());
            seen.push((ev.iteration, ev.adjusting, ev.converged));
            ObserverControl::Continue
        };
        let out = integrate_native_core(&f, &c, None, Some(&mut cb))
            .unwrap()
            .output;
        assert_eq!(seen.len(), out.iterations);
        for (i, &(it, adjusting, _)) in seen.iter().enumerate() {
            assert_eq!(it, i);
            assert_eq!(adjusting, i < 3);
        }
        assert!(!seen.last().unwrap().2, "tau 1e-12 must not converge");
    }

    #[test]
    fn observer_abort_stops_the_run() {
        let f = by_name("f5", 4).unwrap();
        let c = with_plan(cfg(1 << 12, 1e-12), 8, 4, 0);
        let mut fired = 0usize;
        let mut cb = |ev: &IterationEvent| {
            fired += 1;
            if ev.iteration >= 2 {
                ObserverControl::Abort
            } else {
                ObserverControl::Continue
            }
        };
        let out = integrate_native_core(&f, &c, None, Some(&mut cb)).unwrap();
        assert_eq!(out.stop, StopReason::ObserverAbort);
        assert_eq!(out.output.iterations, 3);
        assert_eq!(fired, 3);
        assert!(!out.output.converged);
    }

    #[test]
    fn warm_start_reuses_grid_shape() {
        let f = by_name("f4", 5).unwrap();
        let donor = integrate_native_core(&f, &cfg(1 << 13, 1e-3), None, None).unwrap();
        // Mismatched nb must be rejected with a clear error.
        let mut c = cfg(1 << 13, 1e-3);
        c.nb = 32;
        let err = integrate_native_core(&f, &c, Some(&donor.grid), None)
            .unwrap_err()
            .to_string();
        assert!(err.contains("warm-start"), "{err}");
        // Mismatched grid mode is rejected too (no silent override).
        let mut c_mode = cfg(1 << 13, 1e-3);
        c_mode.grid_mode = GridMode::Shared1D;
        let err = integrate_native_core(&f, &c_mode, Some(&donor.grid), None)
            .unwrap_err()
            .to_string();
        assert!(err.contains("grid mode"), "{err}");
        // Matching shape is accepted.
        let warm = integrate_native_core(&f, &cfg(1 << 13, 1e-3), Some(&donor.grid), None);
        assert!(warm.is_ok());
    }

    #[test]
    fn per_stage_overrides_rejected_on_fixed_backends() {
        use crate::api::Stage;
        use crate::coordinator::EngineBackend;
        let f = by_name("f3", 3).unwrap();
        let mut c = cfg(1 << 12, 1e-3);
        c.plan = RunPlan::warmup_then_final(2, 1 << 10, 3);
        let layout = Layout::compute(3, c.maxcalls, c.nb, c.nblocks).unwrap();
        let mut backend = EngineBackend::uniform(f.clone(), layout, 2);
        let err = drive(&mut backend, &c, None, None)
            .unwrap_err()
            .to_string();
        assert!(err.contains("per-stage overrides"), "{err}");
        // A sampling override is equally rejected.
        let mut c2 = cfg(1 << 12, 1e-3);
        c2.plan = RunPlan::new(vec![
            Stage::adapt(2).with_sampling(Sampling::vegas_plus()),
            Stage::sample(2),
        ]);
        assert!(drive(&mut backend, &c2, None, None).is_err());
        // ...but the same plan runs on the native session path.
        let out = integrate_native_core(&f, &c, None, None).unwrap();
        assert_eq!(out.output.iterations, 5);
    }

    #[test]
    fn warmup_then_final_runs_both_stages() {
        let f = by_name("f4", 5).unwrap();
        let mut c = cfg(1 << 13, 1e-12); // unreachable tau: fixed work
        c.plan = RunPlan::warmup_then_final(3, 1 << 11, 4);
        let mut stages: Vec<(usize, String, bool, bool)> = Vec::new();
        let mut cb = |ev: &IterationEvent| {
            stages.push((
                ev.stage,
                ev.stage_label.to_string(),
                ev.adjusting,
                ev.discarded,
            ));
            ObserverControl::Continue
        };
        let out = integrate_native_core(&f, &c, None, Some(&mut cb)).unwrap();
        assert_eq!(out.output.iterations, 7);
        let warm_calls = Layout::compute(5, 1 << 11, 50, 8).unwrap().calls();
        let final_calls = Layout::compute(5, 1 << 13, 50, 8).unwrap().calls();
        assert_eq!(out.output.calls_used, 3 * warm_calls + 4 * final_calls);
        for (i, (stage, label, adjusting, discarded)) in stages.iter().enumerate() {
            if i < 3 {
                assert_eq!((*stage, *adjusting, *discarded), (0, true, true), "{label}");
            } else {
                assert_eq!((*stage, *adjusting, *discarded), (1, false, false), "{label}");
            }
        }
    }

    #[test]
    fn vegas_plus_converges_and_is_honest() {
        let f = by_name("f4", 5).unwrap();
        let mut c = with_plan(cfg(1 << 16, 1e-3), 20, 12, 2);
        c.seed = 5;
        c.threads = 2;
        c.sampling = Sampling::vegas_plus();
        let out = integrate(&f, &c).unwrap();
        assert!(out.converged, "{out:?}");
        assert_eq!(out.backend, "native-vegas+");
        let truth = f.true_value().unwrap();
        assert!(
            (out.integral - truth).abs() < 4.0 * out.sigma,
            "I={} truth={truth} sigma={}",
            out.integral,
            out.sigma
        );
    }

    #[test]
    fn vegas_plus_beta_zero_bitwise_matches_uniform() {
        // beta = 0 degenerates to the exact uniform split, and both
        // engines share the fixed-task reduction — whole runs agree
        // bit for bit, importance-grid evolution included.
        let f = by_name("f3", 3).unwrap();
        let mut c = with_plan(cfg(1 << 13, 1e-3), 8, 5, 2);
        let uni = integrate(&f, &c).unwrap();
        c.sampling = Sampling::VegasPlus { beta: 0.0 };
        let vp = integrate(&f, &c).unwrap();
        assert_eq!(uni.integral.to_bits(), vp.integral.to_bits());
        assert_eq!(uni.sigma.to_bits(), vp.sigma.to_bits());
        assert_eq!(uni.iterations, vp.iterations);
    }

    #[test]
    fn vegas_plus_bitwise_across_thread_counts() {
        let f = by_name("f4", 5).unwrap();
        let run = |threads: usize| {
            // fixed work: run all iterations
            let mut c = with_plan(cfg(4096, 1e-15), 6, 4, 0);
            c.threads = threads;
            c.sampling = Sampling::vegas_plus();
            integrate(&f, &c).unwrap()
        };
        let a = run(1);
        let b = run(4);
        assert_eq!(a.integral.to_bits(), b.integral.to_bits());
        assert_eq!(a.sigma.to_bits(), b.sigma.to_bits());
        assert_eq!(a.iterations, b.iterations);
    }

    #[test]
    fn vegas_plus_not_worse_than_uniform_on_peaked_integrand() {
        // Same per-iteration budget, fixed iteration count: adaptive
        // allocation should reach a comparable-or-smaller combined
        // sigma on a sharply peaked integrand.
        let f = by_name("f4", 5).unwrap();
        let mk = |sampling: Sampling| {
            let mut c = with_plan(cfg(4096, 1e-15), 10, 8, 2);
            c.seed = 5;
            c.threads = 2;
            c.sampling = sampling;
            integrate(&f, &c).unwrap()
        };
        let uni = mk(Sampling::Uniform);
        let vp = mk(Sampling::vegas_plus());
        assert_eq!(uni.calls_used, vp.calls_used, "same budget per iteration");
        assert!(
            vp.sigma < uni.sigma * 1.05,
            "vegas+ {} should be <= ~uniform {}",
            vp.sigma,
            uni.sigma
        );
    }

    #[test]
    fn vegas_plus_invalid_beta_rejected() {
        let f = by_name("f3", 3).unwrap();
        for beta in [-0.5, 1.5, f64::NAN] {
            let mut c = cfg(1 << 12, 1e-3);
            c.sampling = Sampling::VegasPlus { beta };
            let err = integrate(&f, &c).unwrap_err().to_string();
            assert!(err.contains("beta"), "{err}");
        }
    }

    #[test]
    fn vegas_plus_exports_and_resumes_allocation() {
        // f4 d=5 at 4096 calls: g=4, m=1024, p=4 — enough per-cube
        // headroom (p > 2) for the allocation to actually move.
        let f = by_name("f4", 5).unwrap();
        let mut c = with_plan(cfg(4096, 1e-15), 6, 4, 0);
        c.sampling = Sampling::vegas_plus();
        let donor = integrate_native_core(&f, &c, None, None).unwrap();
        let layout = Layout::compute(5, 4096, c.nb, c.nblocks).unwrap();
        let snap = donor.grid.strat().expect("strat snapshot").clone();
        assert_eq!(snap.beta, 0.75);
        assert_eq!(snap.counts.len(), layout.m);
        assert_eq!(
            snap.counts.iter().map(|&x| x as usize).sum::<usize>(),
            layout.calls()
        );
        assert!(
            snap.counts.iter().any(|&x| x as usize != layout.p),
            "adaptive allocation never moved off the uniform split"
        );

        // Same layout: the snapshot resumes (first iteration samples
        // through the imported counts, so outputs differ from a fresh
        // uniform start).
        let resumed = integrate_native_core(&f, &c, Some(&donor.grid), None).unwrap();
        assert!(resumed.grid.strat().is_some());
        let fresh_grid = donor.grid.clone().without_strat();
        let fresh = integrate_native_core(&f, &c, Some(&fresh_grid), None).unwrap();
        assert_ne!(
            resumed.output.integral.to_bits(),
            fresh.output.integral.to_bits(),
            "resumed allocation must change the sample stream"
        );

        // Different budget (different m): grid warm-starts, allocation
        // silently refreshes to uniform for the new layout.
        let mut c2 = c.clone();
        c2.maxcalls = 1 << 13;
        let refreshed = integrate_native_core(&f, &c2, Some(&donor.grid), None).unwrap();
        assert_eq!(refreshed.output.iterations, 6);
    }

    #[test]
    fn uniform_runs_carry_no_strat_state_and_no_alloc_events() {
        let f = by_name("f5", 4).unwrap();
        let mut c = with_plan(cfg(1 << 12, 1e-15), 4, 2, 0);
        let mut allocs = Vec::new();
        let mut cb = |ev: &IterationEvent| {
            allocs.push(ev.alloc);
            ObserverControl::Continue
        };
        let out = integrate_native_core(&f, &c, None, Some(&mut cb)).unwrap();
        assert!(out.grid.strat().is_none());
        assert!(allocs.iter().all(|a| a.is_none()));

        c.sampling = Sampling::vegas_plus();
        let mut allocs = Vec::new();
        let mut cb = |ev: &IterationEvent| {
            allocs.push(ev.alloc);
            ObserverControl::Continue
        };
        let out = integrate_native_core(&f, &c, None, Some(&mut cb)).unwrap();
        assert!(out.grid.strat().is_some());
        assert_eq!(allocs.len(), out.output.iterations);
        for a in allocs {
            let a = a.expect("vegas+ iterations expose allocation stats");
            assert!(a.min >= 2);
            assert!(a.max >= a.min);
            assert!(a.total > 0);
        }
    }

    #[test]
    fn vegas_plus_suspend_resume_survives_reallocation_state() {
        // Satellite regression for the removed RefCell shims: the
        // engines' `&mut self` update hook must leave the stratified
        // reallocation state exactly where a suspend/resume expects
        // it. Drive an EngineBackend for two iterations, export its
        // snapshot, rebuild from the snapshot, and the next iteration
        // must match the uninterrupted backend bitwise.
        use crate::coordinator::EngineBackend;
        use crate::grid::Bins;
        let f = by_name("f4", 5).unwrap();
        let layout = Layout::compute(5, 4096, 16, 1).unwrap();
        let bins = Bins::uniform(5, 16);
        let beta = 0.75;
        let mut donor = EngineBackend::vegas_plus(f.clone(), layout, 2, beta, None).unwrap();
        for it in 0..2u32 {
            donor.run(&bins, 11, it, true).unwrap();
        }
        let snap = donor.strat_export().expect("stratified export");
        let mut resumed =
            EngineBackend::vegas_plus(f.clone(), layout, 4, beta, Some(&snap)).unwrap();
        let (rd, cd) = donor.run(&bins, 11, 2, true).unwrap();
        let (rr, cr) = resumed.run(&bins, 11, 2, true).unwrap();
        assert_eq!(rd.integral.to_bits(), rr.integral.to_bits());
        assert_eq!(rd.variance.to_bits(), rr.variance.to_bits());
        for (a, b) in cd.unwrap().iter().zip(&cr.unwrap()) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
        // alloc_stats describes the allocation the pass ran with.
        let sd = donor.alloc_stats().expect("stats after run");
        let sr = resumed.alloc_stats().expect("stats after run");
        assert_eq!(sd.min, sr.min);
        assert_eq!(sd.max, sr.max);
        assert_eq!(sd.total, sr.total);
    }
}
