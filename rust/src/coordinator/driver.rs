//! The m-Cubes iteration driver (Algorithm 2): two-phase loop with bin
//! adjustment, weighted estimates, chi^2 guard, and convergence checks.

use super::backend::VSampleBackend;
use crate::error::{Error, Result};
use crate::estimator::{Convergence, WeightedEstimator};
use crate::grid::{Bins, GridMode};
use crate::integrands::Integrand;
use crate::strat::Layout;
use crate::util::threadpool::default_threads;
use std::time::Instant;

/// Everything the driver needs to know about one integration job.
#[derive(Debug, Clone)]
pub struct JobConfig {
    /// Evaluation budget per iteration (Algorithm 2 `maxcalls`).
    pub maxcalls: usize,
    /// Importance bins per axis.
    pub nb: usize,
    /// Grid programs / thread groups (must match artifact for PJRT).
    pub nblocks: usize,
    /// Target relative error.
    pub tau_rel: f64,
    /// Total iteration cap (Algorithm 2 `itmax`).
    pub itmax: usize,
    /// Iterations with bin adjustment (Algorithm 2 `ita`).
    pub ita: usize,
    /// Iterations to discard from the weighted estimate (importance-grid
    /// warm-up). Keeps early wildly-off iterations from polluting the
    /// combined estimate (the paper's chi^2 criterion, §5.1).
    pub skip: usize,
    /// Reset the estimator when chi2/dof blows past the convergence
    /// guard during the adjust phase (recovers from a bad warm-up).
    pub reset_on_inconsistency: bool,
    /// RNG seed.
    pub seed: u32,
    /// Grid mode: PerAxis (m-Cubes) or Shared1D (m-Cubes1D).
    pub grid_mode: GridMode,
    /// Worker threads for the native engine.
    pub threads: usize,
}

impl Default for JobConfig {
    fn default() -> Self {
        JobConfig {
            maxcalls: 1 << 17,
            nb: 50,
            nblocks: 8,
            tau_rel: 1e-3,
            itmax: 15,
            ita: 10,
            skip: 2,
            reset_on_inconsistency: true,
            seed: 42,
            grid_mode: GridMode::PerAxis,
            threads: default_threads(),
        }
    }
}

impl JobConfig {
    pub fn validate(&self) -> Result<()> {
        if self.itmax == 0 {
            return Err(Error::Config("itmax must be >= 1".into()));
        }
        if self.ita > self.itmax {
            return Err(Error::Config(format!(
                "ita {} > itmax {}",
                self.ita, self.itmax
            )));
        }
        if !(self.tau_rel > 0.0) {
            return Err(Error::Config("tau_rel must be > 0".into()));
        }
        if self.skip >= self.itmax {
            return Err(Error::Config(format!(
                "skip {} >= itmax {}",
                self.skip, self.itmax
            )));
        }
        Ok(())
    }

    /// Convergence policy derived from this config.
    pub fn convergence(&self) -> Convergence {
        Convergence::with_tau(self.tau_rel)
    }
}

/// Final result of an integration job.
#[derive(Debug, Clone)]
pub struct IntegrationOutput {
    pub integral: f64,
    pub sigma: f64,
    pub chi2_dof: f64,
    pub rel_err: f64,
    pub iterations: usize,
    pub converged: bool,
    /// Total integrand evaluations consumed.
    pub calls_used: usize,
    /// Wall time of the whole job (seconds).
    pub total_time: f64,
    /// Time inside backend.run — the paper's "kernel time" (seconds).
    pub kernel_time: f64,
    /// Backend label.
    pub backend: &'static str,
}

/// Detailed per-iteration trace (used by benches/ablations).
#[derive(Debug, Clone, Default)]
pub struct DriverOutput {
    pub output: Option<IntegrationOutput>,
    pub iteration_estimates: Vec<(f64, f64)>, // (I_j, sigma_j)
}

/// Run the two-phase m-Cubes loop on any backend.
pub fn run_driver(backend: &dyn VSampleBackend, cfg: &JobConfig) -> Result<IntegrationOutput> {
    let (out, _) = run_driver_traced(backend, cfg)?;
    Ok(out)
}

/// Like `run_driver` but also returns the per-iteration estimates.
pub fn run_driver_traced(
    backend: &dyn VSampleBackend,
    cfg: &JobConfig,
) -> Result<(IntegrationOutput, DriverOutput)> {
    cfg.validate()?;
    let layout = backend.layout();
    let conv = cfg.convergence();
    let mut bins = Bins::uniform_mode(layout.d, layout.nb, cfg.grid_mode);
    let mut est = WeightedEstimator::new();
    let mut trace = DriverOutput::default();

    let t_start = Instant::now();
    let mut kernel_time = 0.0f64;
    let mut calls_used = 0usize;
    let mut iterations = 0usize;
    let mut converged = false;

    for it in 0..cfg.itmax {
        let adjust = it < cfg.ita;
        let t0 = Instant::now();
        let (r, contrib) = backend.run(&bins, cfg.seed, it as u32, adjust)?;
        kernel_time += t0.elapsed().as_secs_f64();
        calls_used += layout.calls();
        iterations += 1;

        if it >= cfg.skip {
            est.push(r);
        }
        trace.iteration_estimates.push((r.integral, r.variance.sqrt()));

        // Grid refinement happens before the convergence decision so a
        // converged final iteration still leaves an adapted grid behind.
        if adjust {
            if let Some(c) = contrib {
                bins.adjust(&c);
            }
            if cfg.reset_on_inconsistency
                && est.iterations() >= 2
                && est.chi2_dof() > conv.max_chi2_dof
            {
                // Importance grid was still moving: drop the stale
                // estimates, keep the (better) grid.
                est.reset();
            }
        }

        if conv.satisfied(&est) {
            converged = true;
            break;
        }
    }

    let output = IntegrationOutput {
        integral: est.integral(),
        sigma: est.sigma(),
        chi2_dof: est.chi2_dof(),
        rel_err: est.rel_err(),
        iterations,
        converged,
        calls_used,
        total_time: t_start.elapsed().as_secs_f64(),
        kernel_time,
        backend: backend.name(),
    };
    trace.output = Some(output.clone());
    Ok((output, trace))
}

/// Convenience: integrate `f` with the native engine.
pub fn integrate_native(f: &dyn Integrand, cfg: &JobConfig) -> Result<IntegrationOutput> {
    let layout = Layout::compute(f.dim(), cfg.maxcalls, cfg.nb, cfg.nblocks)?;
    // NativeBackend holds an Arc; wrap via a thin adapter around &dyn.
    struct Borrowed<'a> {
        f: &'a dyn Integrand,
        layout: Layout,
        threads: usize,
    }
    impl<'a> VSampleBackend for Borrowed<'a> {
        fn layout(&self) -> Layout {
            self.layout
        }
        fn bounds(&self) -> (f64, f64) {
            (self.f.lo(), self.f.hi())
        }
        fn name(&self) -> &'static str {
            "native"
        }
        fn run(
            &self,
            bins: &Bins,
            seed: u32,
            iteration: u32,
            adjust: bool,
        ) -> Result<(crate::estimator::IterationResult, Option<Vec<f64>>)> {
            let opts = crate::engine::VSampleOpts {
                seed,
                iteration,
                adjust,
                threads: self.threads,
            };
            Ok(crate::engine::NativeEngine.vsample(self.f, &self.layout, bins, &opts))
        }
    }
    let backend = Borrowed {
        f,
        layout,
        threads: cfg.threads,
    };
    run_driver(&backend, cfg)
}

/// Escalating-precision integration: runs the driver at increasing call
/// budgets (x`escalation_factor` per step) until `tau_rel` is met,
/// carrying the adapted grid across levels — the strategy behind the
/// paper's high-precision runs (Fig. 1/2).
pub fn integrate_native_adaptive(
    f: &dyn Integrand,
    base: &JobConfig,
    max_escalations: usize,
    escalation_factor: usize,
) -> Result<IntegrationOutput> {
    let mut cfg = base.clone();
    let mut last: Option<IntegrationOutput> = None;
    let mut total_time = 0.0;
    let mut kernel_time = 0.0;
    let mut calls_used = 0;
    let mut iterations = 0;
    for level in 0..=max_escalations {
        let out = integrate_native(f, &cfg)?;
        total_time += out.total_time;
        kernel_time += out.kernel_time;
        calls_used += out.calls_used;
        iterations += out.iterations;
        let converged = out.converged;
        last = Some(IntegrationOutput {
            total_time,
            kernel_time,
            calls_used,
            iterations,
            ..out
        });
        if converged {
            break;
        }
        if level < max_escalations {
            cfg.maxcalls *= escalation_factor;
            // Fresh seed per level so escalations resample.
            cfg.seed = cfg.seed.wrapping_add(0x9E37_79B9);
        }
    }
    last.ok_or_else(|| Error::Config("no escalation levels ran".into()))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::integrands::by_name;

    fn cfg(calls: usize, tau: f64) -> JobConfig {
        JobConfig {
            maxcalls: calls,
            nb: 50,
            tau_rel: tau,
            itmax: 15,
            ita: 10,
            skip: 2,
            seed: 11,
            threads: 4,
            ..Default::default()
        }
    }

    #[test]
    fn converges_on_smooth_integrands() {
        for (name, d, calls) in [("f5", 8, 1 << 15), ("f3", 3, 1 << 14), ("f2", 6, 1 << 15)] {
            let f = by_name(name, d).unwrap();
            let out = integrate_native(&*f, &cfg(calls, 1e-3)).unwrap();
            assert!(out.converged, "{name} did not converge: {out:?}");
            let truth = f.true_value().unwrap();
            let rel = ((out.integral - truth) / truth).abs();
            // 1e-3 claimed; allow 5x for statistical slop across seeds.
            assert!(rel < 5e-3, "{name}: rel err {rel}, out {out:?}");
            assert!(out.chi2_dof < 5.0, "{name}: chi2 {}", out.chi2_dof);
        }
    }

    #[test]
    fn error_estimate_is_honest() {
        // |estimate - truth| should usually be within ~3 claimed sigmas.
        let f = by_name("f4", 5).unwrap();
        let out = integrate_native(&*f, &cfg(1 << 15, 1e-3)).unwrap();
        let truth = f.true_value().unwrap();
        assert!(
            (out.integral - truth).abs() < 4.0 * out.sigma,
            "bias: {} vs sigma {}",
            (out.integral - truth).abs(),
            out.sigma
        );
    }

    #[test]
    fn two_phase_runs_na_iterations() {
        let f = by_name("f5", 4).unwrap();
        let mut c = cfg(1 << 12, 1e-12); // unreachable tau: run all iters
        c.itmax = 6;
        c.ita = 3;
        c.skip = 0;
        let out = integrate_native(&*f, &c).unwrap();
        assert!(!out.converged);
        assert_eq!(out.iterations, 6);
        assert_eq!(out.calls_used, 6 * Layout::compute(4, 1 << 12, 50, 8).unwrap().calls());
    }

    #[test]
    fn validates_config() {
        let f = by_name("f4", 5).unwrap();
        let mut c = cfg(1 << 12, 1e-3);
        c.ita = 99;
        c.itmax = 5;
        assert!(integrate_native(&*f, &c).is_err());
        let mut c2 = cfg(1 << 12, 1e-3);
        c2.skip = 20;
        c2.itmax = 10;
        assert!(integrate_native(&*f, &c2).is_err());
    }

    #[test]
    fn adaptive_escalates_until_converged() {
        let f = by_name("f4", 8).unwrap();
        let mut base = cfg(1 << 12, 1e-3);
        base.itmax = 10;
        base.ita = 8;
        let out = integrate_native_adaptive(&*f, &base, 4, 4).unwrap();
        assert!(out.converged, "{out:?}");
        let truth = f.true_value().unwrap();
        let rel = ((out.integral - truth) / truth).abs();
        assert!(rel < 1e-2, "rel {rel}");
    }

    #[test]
    fn onedim_mode_works_on_symmetric() {
        let f = by_name("f4", 5).unwrap();
        let mut c = cfg(1 << 15, 1e-3);
        c.itmax = 20;
        c.grid_mode = GridMode::Shared1D;
        let out = integrate_native(&*f, &c).unwrap();
        assert!(out.converged, "{out:?}");
        let truth = f.true_value().unwrap();
        assert!(((out.integral - truth) / truth).abs() < 5e-3);
    }

    #[test]
    fn seed_reproducibility() {
        let f = by_name("f3", 3).unwrap();
        let a = integrate_native(&*f, &cfg(1 << 13, 1e-3)).unwrap();
        let b = integrate_native(&*f, &cfg(1 << 13, 1e-3)).unwrap();
        assert_eq!(a.integral, b.integral);
        assert_eq!(a.sigma, b.sigma);
    }
}
