//! The m-Cubes iteration driver (Algorithm 2): two-phase loop with bin
//! adjustment, weighted estimates, chi^2 guard, and convergence checks.
//!
//! `drive` is the single driver core. It accepts an optional warm-start
//! grid (`api::GridState`) and an optional per-iteration observer
//! (`api::IterationEvent`), and returns both the integration output and
//! the final adapted grid. The free functions the seed shipped
//! (`run_driver`, `run_driver_traced`, `integrate_native`,
//! `integrate_native_adaptive`) remain as deprecated shims over it;
//! new code goes through `api::Integrator`.

use super::backend::VSampleBackend;
use crate::api::{GridState, IterationEvent, StratSnapshot};
use crate::engine::vsample_stratified;
use crate::error::{Error, Result};
use crate::estimator::{Convergence, WeightedEstimator};
use crate::grid::{Bins, GridMode};
use crate::integrands::Integrand;
use crate::strat::{AllocStats, Allocation, Layout, Sampling};
use crate::util::threadpool::default_threads;
use std::cell::RefCell;
use std::time::Instant;

/// Everything the driver needs to know about one integration job.
#[derive(Debug, Clone)]
pub struct JobConfig {
    /// Evaluation budget per iteration (Algorithm 2 `maxcalls`).
    pub maxcalls: usize,
    /// Importance bins per axis.
    pub nb: usize,
    /// Grid programs / thread groups (must match artifact for PJRT).
    pub nblocks: usize,
    /// Target relative error.
    pub tau_rel: f64,
    /// Total iteration cap (Algorithm 2 `itmax`).
    pub itmax: usize,
    /// Iterations with bin adjustment (Algorithm 2 `ita`).
    pub ita: usize,
    /// Iterations to discard from the weighted estimate (importance-grid
    /// warm-up). Keeps early wildly-off iterations from polluting the
    /// combined estimate (the paper's chi^2 criterion, §5.1).
    pub skip: usize,
    /// Reset the estimator when chi2/dof blows past the convergence
    /// guard during the adjust phase (recovers from a bad warm-up).
    pub reset_on_inconsistency: bool,
    /// RNG seed.
    pub seed: u32,
    /// Grid mode: PerAxis (m-Cubes) or Shared1D (m-Cubes1D).
    pub grid_mode: GridMode,
    /// Per-cube sample allocation: uniform m-Cubes (`Sampling::Uniform`)
    /// or VEGAS+ adaptive stratification (`Sampling::VegasPlus`).
    /// Native engine only — the PJRT artifacts compile the uniform
    /// layout.
    pub sampling: Sampling,
    /// Worker threads for the native engine.
    pub threads: usize,
}

impl Default for JobConfig {
    fn default() -> Self {
        JobConfig {
            maxcalls: 1 << 17,
            nb: 50,
            nblocks: 8,
            tau_rel: 1e-3,
            itmax: 15,
            ita: 10,
            skip: 2,
            reset_on_inconsistency: true,
            seed: 42,
            grid_mode: GridMode::PerAxis,
            sampling: Sampling::Uniform,
            threads: default_threads(),
        }
    }
}

impl JobConfig {
    pub fn validate(&self) -> Result<()> {
        if self.maxcalls < 4 {
            return Err(Error::Config(format!(
                "maxcalls must be >= 4 (the layout needs at least 2 samples \
                 in at least 1 cube), got {}",
                self.maxcalls
            )));
        }
        if self.nb < 2 {
            return Err(Error::Config(format!(
                "nb (importance bins per axis) must be >= 2, got {}",
                self.nb
            )));
        }
        if self.nblocks == 0 {
            return Err(Error::Config(
                "nblocks (grid programs) must be >= 1, got 0".into(),
            ));
        }
        if self.itmax == 0 {
            return Err(Error::Config("itmax must be >= 1".into()));
        }
        if self.ita > self.itmax {
            return Err(Error::Config(format!(
                "ita {} > itmax {}",
                self.ita, self.itmax
            )));
        }
        if !(self.tau_rel > 0.0) {
            return Err(Error::Config("tau_rel must be > 0".into()));
        }
        if self.skip >= self.itmax {
            return Err(Error::Config(format!(
                "skip {} >= itmax {}",
                self.skip, self.itmax
            )));
        }
        self.sampling.validate()?;
        Ok(())
    }

    /// Convergence policy derived from this config.
    pub fn convergence(&self) -> Convergence {
        Convergence::with_tau(self.tau_rel)
    }
}

/// Final result of an integration job.
#[derive(Debug, Clone)]
pub struct IntegrationOutput {
    pub integral: f64,
    pub sigma: f64,
    pub chi2_dof: f64,
    pub rel_err: f64,
    pub iterations: usize,
    pub converged: bool,
    /// Total integrand evaluations consumed.
    pub calls_used: usize,
    /// Wall time of the whole job (seconds).
    pub total_time: f64,
    /// Time inside backend.run — the paper's "kernel time" (seconds).
    pub kernel_time: f64,
    /// Backend label.
    pub backend: &'static str,
}

/// Detailed per-iteration trace (legacy; superseded by observers on
/// `drive` / `api::Integrator::observe`).
#[derive(Debug, Clone, Default)]
pub struct DriverOutput {
    pub output: Option<IntegrationOutput>,
    pub iteration_estimates: Vec<(f64, f64)>, // (I_j, sigma_j)
}

/// `drive` result: the integration output plus the adapted grid, ready
/// to warm-start a later run.
#[derive(Debug, Clone)]
pub struct DriveOutcome {
    pub output: IntegrationOutput,
    pub grid: GridState,
}

/// Run the two-phase m-Cubes loop on any backend.
///
/// * `warm_start` — adapted grid from a previous run. Must match the
///   backend layout's `(d, nb)` and `cfg.grid_mode` — a mismatch is a
///   config error, never a silent override. `None` starts from a
///   uniform grid.
/// * `observer` — called once per iteration with an
///   [`IterationEvent`] after grid adjustment and the convergence
///   decision.
pub fn drive(
    backend: &dyn VSampleBackend,
    cfg: &JobConfig,
    warm_start: Option<&GridState>,
    mut observer: Option<&mut dyn FnMut(&IterationEvent)>,
) -> Result<DriveOutcome> {
    cfg.validate()?;
    let layout = backend.layout();
    let conv = cfg.convergence();
    let mut bins = match warm_start {
        Some(gs) => {
            gs.compatible(layout.d, layout.nb)?;
            if gs.mode() != cfg.grid_mode {
                return Err(Error::Config(format!(
                    "warm-start grid mode {:?} != configured grid mode {:?}; \
                     adapt the donor in the same mode (or match grid_mode to \
                     the donor)",
                    gs.mode(),
                    cfg.grid_mode
                )));
            }
            gs.bins().clone()
        }
        None => Bins::uniform_mode(layout.d, layout.nb, cfg.grid_mode),
    };
    let mut est = WeightedEstimator::new();

    let t_start = Instant::now();
    let mut kernel_time = 0.0f64;
    let mut calls_used = 0usize;
    let mut iterations = 0usize;
    let mut converged = false;

    for it in 0..cfg.itmax {
        let adjust = it < cfg.ita;
        let t0 = Instant::now();
        let (r, contrib) = backend.run(&bins, cfg.seed, it as u32, adjust)?;
        kernel_time += t0.elapsed().as_secs_f64();
        calls_used += layout.calls();
        iterations += 1;

        if it >= cfg.skip {
            est.push(r);
        }

        // Grid refinement happens before the convergence decision so a
        // converged final iteration still leaves an adapted grid behind.
        let mut estimator_reset = false;
        if adjust {
            if let Some(c) = contrib {
                bins.adjust(&c);
            }
            if cfg.reset_on_inconsistency
                && est.iterations() >= 2
                && est.chi2_dof() > conv.max_chi2_dof
            {
                // Importance grid was still moving: drop the stale
                // estimates, keep the (better) grid.
                est.reset();
                estimator_reset = true;
            }
        }

        if conv.satisfied(&est) {
            converged = true;
        }

        if let Some(cb) = observer.as_mut() {
            cb(&IterationEvent {
                iteration: it,
                adjusting: adjust,
                estimate: r,
                integral: est.integral(),
                sigma: est.sigma(),
                chi2_dof: est.chi2_dof(),
                rel_err: est.rel_err(),
                estimator_reset,
                converged,
                alloc: backend.alloc_stats(),
                grid: &bins,
            });
        }

        if converged {
            break;
        }
    }

    let output = IntegrationOutput {
        integral: est.integral(),
        sigma: est.sigma(),
        chi2_dof: est.chi2_dof(),
        rel_err: est.rel_err(),
        iterations,
        converged,
        calls_used,
        total_time: t_start.elapsed().as_secs_f64(),
        kernel_time,
        backend: backend.name(),
    };
    Ok(DriveOutcome {
        output,
        grid: GridState::from_bins(bins),
    })
}

/// Thin adapter: run a `&dyn Integrand` on the native engine without
/// requiring an `Arc`.
struct BorrowedNative<'a> {
    f: &'a dyn Integrand,
    layout: Layout,
    threads: usize,
}

impl<'a> VSampleBackend for BorrowedNative<'a> {
    fn layout(&self) -> Layout {
        self.layout
    }

    fn bounds(&self) -> crate::strat::Bounds {
        self.f.bounds()
    }

    fn name(&self) -> &'static str {
        "native"
    }

    fn run(
        &self,
        bins: &Bins,
        seed: u32,
        iteration: u32,
        adjust: bool,
    ) -> Result<(crate::estimator::IterationResult, Option<Vec<f64>>)> {
        let opts = crate::engine::VSampleOpts {
            seed,
            iteration,
            adjust,
            threads: self.threads,
        };
        Ok(crate::engine::NativeEngine.vsample(self.f, &self.layout, bins, &opts))
    }
}

/// Mutable per-run state of the stratified backend: the live
/// allocation plus the stats snapshot of the iteration that just ran.
struct StratCell {
    alloc: Allocation,
    last: Option<AllocStats>,
}

/// VEGAS+ stratified twin of [`BorrowedNative`]: drives
/// `engine::stratified::vsample_stratified` with a live [`Allocation`],
/// re-apportioning the per-iteration budget after every pass. The
/// driver itself stays allocation-agnostic — it only sees the
/// `VSampleBackend` contract plus `alloc_stats` for observers.
struct BorrowedStratified<'a> {
    f: &'a dyn Integrand,
    layout: Layout,
    threads: usize,
    beta: f64,
    /// Per-iteration call budget (`layout.calls()`, matching the
    /// uniform engine so `calls_used` accounting is identical).
    budget: usize,
    state: RefCell<StratCell>,
}

impl<'a> VSampleBackend for BorrowedStratified<'a> {
    fn layout(&self) -> Layout {
        self.layout
    }

    fn bounds(&self) -> crate::strat::Bounds {
        self.f.bounds()
    }

    fn name(&self) -> &'static str {
        "native-vegas+"
    }

    fn run(
        &self,
        bins: &Bins,
        seed: u32,
        iteration: u32,
        adjust: bool,
    ) -> Result<(crate::estimator::IterationResult, Option<Vec<f64>>)> {
        let mut cell = self.state.borrow_mut();
        let StratCell { alloc, last } = &mut *cell;
        *last = Some(alloc.stats());
        let opts = crate::engine::VSampleOpts {
            seed,
            iteration,
            adjust,
            threads: self.threads,
        };
        let out = vsample_stratified(self.f, &self.layout, bins, alloc, &opts);
        // Re-apportion for the next iteration from the freshly damped
        // accumulator (cheap; also leaves the exported snapshot ready
        // for warm starts even when this was the final iteration).
        alloc.reallocate(self.budget, self.beta);
        Ok(out)
    }

    fn alloc_stats(&self) -> Option<AllocStats> {
        self.state.borrow().last
    }
}

/// Native-engine drive over a borrowed integrand — the shared core the
/// facade, the service, and the deprecated shims all call. Dispatches
/// on `cfg.sampling` between the uniform m-Cubes engine and the VEGAS+
/// stratified path.
pub(crate) fn integrate_native_core(
    f: &dyn Integrand,
    cfg: &JobConfig,
    warm_start: Option<&GridState>,
    observer: Option<&mut dyn FnMut(&IterationEvent)>,
) -> Result<DriveOutcome> {
    cfg.validate()?;
    let layout = Layout::compute(f.dim(), cfg.maxcalls, cfg.nb, cfg.nblocks)?;
    match cfg.sampling {
        Sampling::Uniform => {
            let backend = BorrowedNative {
                f,
                layout,
                threads: cfg.threads,
            };
            drive(&backend, cfg, warm_start, observer)
        }
        Sampling::VegasPlus { beta } => {
            // Resume the donor's allocation when its layout matches;
            // allocations are per-cube state, so a different cube
            // count (different maxcalls) starts fresh while the
            // importance grid still warm-starts. The re-apportion
            // below is a pure function of (damped, budget, beta): for
            // a matching budget it reproduces the snapshot's counts
            // bit-for-bit, and for a same-m / different-p layout
            // (escalation can hit this) it corrects the counts to the
            // new call budget instead of silently under-sampling.
            let alloc = match warm_start.and_then(|gs| gs.strat()) {
                Some(s) if s.counts.len() == layout.m => {
                    let mut a = Allocation::from_parts(s.counts.clone(), s.damped.clone())?;
                    a.reallocate(layout.calls(), beta);
                    a
                }
                _ => Allocation::uniform(&layout),
            };
            let backend = BorrowedStratified {
                f,
                layout,
                threads: cfg.threads,
                beta,
                budget: layout.calls(),
                state: RefCell::new(StratCell { alloc, last: None }),
            };
            let mut outcome = drive(&backend, cfg, warm_start, observer)?;
            let cell = backend.state.into_inner();
            outcome.grid = outcome.grid.with_strat(StratSnapshot {
                beta,
                counts: cell.alloc.counts().to_vec(),
                damped: cell.alloc.damped().to_vec(),
            });
            Ok(outcome)
        }
    }
}

/// Escalating-precision native integration: runs the driver at
/// increasing call budgets (x`factor` per level) until `tau_rel` is
/// met, genuinely carrying the adapted grid across levels — the
/// strategy behind the paper's high-precision runs (Fig. 1/2).
/// Iteration indices in observer events are cumulative across levels.
pub(crate) fn escalate_native(
    f: &dyn Integrand,
    base: &JobConfig,
    max_escalations: usize,
    factor: usize,
    warm_start: Option<&GridState>,
    mut observer: Option<&mut dyn FnMut(&IterationEvent)>,
) -> Result<DriveOutcome> {
    if factor < 2 {
        return Err(Error::Config(format!(
            "escalation factor must be >= 2, got {factor}"
        )));
    }
    let mut cfg = base.clone();
    let mut grid: Option<GridState> = warm_start.cloned();
    let mut last: Option<DriveOutcome> = None;
    let mut total_time = 0.0;
    let mut kernel_time = 0.0;
    let mut calls_used = 0;
    let mut iterations = 0;
    for level in 0..=max_escalations {
        let outcome = {
            let base_it = iterations;
            match observer.as_deref_mut() {
                Some(cb) => {
                    let mut shifted = |ev: &IterationEvent| {
                        cb(&IterationEvent {
                            iteration: base_it + ev.iteration,
                            ..*ev
                        })
                    };
                    integrate_native_core(f, &cfg, grid.as_ref(), Some(&mut shifted))?
                }
                None => integrate_native_core(f, &cfg, grid.as_ref(), None)?,
            }
        };
        total_time += outcome.output.total_time;
        kernel_time += outcome.output.kernel_time;
        calls_used += outcome.output.calls_used;
        iterations += outcome.output.iterations;
        let converged = outcome.output.converged;
        grid = Some(outcome.grid.clone());
        last = Some(DriveOutcome {
            output: IntegrationOutput {
                total_time,
                kernel_time,
                calls_used,
                iterations,
                ..outcome.output
            },
            grid: outcome.grid,
        });
        if converged {
            break;
        }
        if level < max_escalations {
            cfg.maxcalls *= factor;
            // Fresh seed per level so escalations resample.
            cfg.seed = cfg.seed.wrapping_add(0x9E37_79B9);
        }
    }
    last.ok_or_else(|| Error::Config("no escalation levels ran".into()))
}

/// Run the two-phase m-Cubes loop on any backend (cold start, no
/// observers).
#[cfg(feature = "legacy-api")]
#[deprecated(
    since = "0.2.0",
    note = "use `api::Integrator`, or `coordinator::drive` for raw backends"
)]
pub fn run_driver(backend: &dyn VSampleBackend, cfg: &JobConfig) -> Result<IntegrationOutput> {
    drive(backend, cfg, None, None).map(|o| o.output)
}

/// Like `run_driver` but also returns the per-iteration estimates.
#[cfg(feature = "legacy-api")]
#[deprecated(
    since = "0.2.0",
    note = "use an observer on `api::Integrator::observe` (or `drive`) instead"
)]
pub fn run_driver_traced(
    backend: &dyn VSampleBackend,
    cfg: &JobConfig,
) -> Result<(IntegrationOutput, DriverOutput)> {
    let mut estimates: Vec<(f64, f64)> = Vec::new();
    let mut cb = |ev: &IterationEvent| {
        estimates.push((ev.estimate.integral, ev.estimate.variance.sqrt()));
    };
    let outcome = drive(backend, cfg, None, Some(&mut cb))?;
    let trace = DriverOutput {
        output: Some(outcome.output.clone()),
        iteration_estimates: estimates,
    };
    Ok((outcome.output, trace))
}

/// Convenience: integrate `f` with the native engine.
#[cfg(feature = "legacy-api")]
#[deprecated(since = "0.2.0", note = "use `api::Integrator::new(f).run()` instead")]
pub fn integrate_native(f: &dyn Integrand, cfg: &JobConfig) -> Result<IntegrationOutput> {
    integrate_native_core(f, cfg, None, None).map(|o| o.output)
}

/// Escalating-precision integration (see `escalate_native`).
#[cfg(feature = "legacy-api")]
#[deprecated(
    since = "0.2.0",
    note = "use `api::Integrator::new(f).escalate(levels, factor).run()` instead"
)]
pub fn integrate_native_adaptive(
    f: &dyn Integrand,
    base: &JobConfig,
    max_escalations: usize,
    escalation_factor: usize,
) -> Result<IntegrationOutput> {
    escalate_native(f, base, max_escalations, escalation_factor, None, None).map(|o| o.output)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::integrands::by_name;

    fn cfg(calls: usize, tau: f64) -> JobConfig {
        JobConfig {
            maxcalls: calls,
            nb: 50,
            tau_rel: tau,
            itmax: 15,
            ita: 10,
            skip: 2,
            seed: 11,
            threads: 4,
            ..Default::default()
        }
    }

    fn integrate(f: &dyn Integrand, c: &JobConfig) -> Result<IntegrationOutput> {
        integrate_native_core(f, c, None, None).map(|o| o.output)
    }

    #[test]
    fn converges_on_smooth_integrands() {
        for (name, d, calls) in [("f5", 8, 1 << 15), ("f3", 3, 1 << 14), ("f2", 6, 1 << 15)] {
            let f = by_name(name, d).unwrap();
            let out = integrate(&*f, &cfg(calls, 1e-3)).unwrap();
            assert!(out.converged, "{name} did not converge: {out:?}");
            let truth = f.true_value().unwrap();
            let rel = ((out.integral - truth) / truth).abs();
            // 1e-3 claimed; allow 5x for statistical slop across seeds.
            assert!(rel < 5e-3, "{name}: rel err {rel}, out {out:?}");
            assert!(out.chi2_dof < 5.0, "{name}: chi2 {}", out.chi2_dof);
        }
    }

    #[test]
    fn error_estimate_is_honest() {
        // |estimate - truth| should usually be within ~3 claimed sigmas.
        let f = by_name("f4", 5).unwrap();
        let out = integrate(&*f, &cfg(1 << 15, 1e-3)).unwrap();
        let truth = f.true_value().unwrap();
        assert!(
            (out.integral - truth).abs() < 4.0 * out.sigma,
            "bias: {} vs sigma {}",
            (out.integral - truth).abs(),
            out.sigma
        );
    }

    #[test]
    fn two_phase_runs_na_iterations() {
        let f = by_name("f5", 4).unwrap();
        let mut c = cfg(1 << 12, 1e-12); // unreachable tau: run all iters
        c.itmax = 6;
        c.ita = 3;
        c.skip = 0;
        let out = integrate(&*f, &c).unwrap();
        assert!(!out.converged);
        assert_eq!(out.iterations, 6);
        assert_eq!(
            out.calls_used,
            6 * Layout::compute(4, 1 << 12, 50, 8).unwrap().calls()
        );
    }

    #[test]
    fn validates_config() {
        let f = by_name("f4", 5).unwrap();
        let mut c = cfg(1 << 12, 1e-3);
        c.ita = 99;
        c.itmax = 5;
        assert!(integrate(&*f, &c).is_err());
        let mut c2 = cfg(1 << 12, 1e-3);
        c2.skip = 20;
        c2.itmax = 10;
        assert!(integrate(&*f, &c2).is_err());
    }

    #[test]
    fn validate_rejects_zero_budget_and_shape() {
        assert!(JobConfig::default().validate().is_ok());

        let zero_calls = JobConfig {
            maxcalls: 0,
            ..Default::default()
        };
        let err = zero_calls.validate().unwrap_err().to_string();
        assert!(err.contains("maxcalls"), "{err}");
        assert!(JobConfig {
            maxcalls: 3,
            ..Default::default()
        }
        .validate()
        .is_err());

        let zero_nb = JobConfig {
            nb: 0,
            ..Default::default()
        };
        let err = zero_nb.validate().unwrap_err().to_string();
        assert!(err.contains("nb"), "{err}");
        assert!(JobConfig {
            nb: 1,
            ..Default::default()
        }
        .validate()
        .is_err());

        let zero_blocks = JobConfig {
            nblocks: 0,
            ..Default::default()
        };
        let err = zero_blocks.validate().unwrap_err().to_string();
        assert!(err.contains("nblocks"), "{err}");
    }

    #[test]
    fn adaptive_escalates_until_converged() {
        let f = by_name("f4", 8).unwrap();
        let mut base = cfg(1 << 12, 1e-3);
        base.itmax = 10;
        base.ita = 8;
        let out = escalate_native(&*f, &base, 4, 4, None, None).unwrap().output;
        assert!(out.converged, "{out:?}");
        let truth = f.true_value().unwrap();
        let rel = ((out.integral - truth) / truth).abs();
        assert!(rel < 1e-2, "rel {rel}");
    }

    #[test]
    fn onedim_mode_works_on_symmetric() {
        let f = by_name("f4", 5).unwrap();
        let mut c = cfg(1 << 15, 1e-3);
        c.itmax = 20;
        c.grid_mode = GridMode::Shared1D;
        let out = integrate(&*f, &c).unwrap();
        assert!(out.converged, "{out:?}");
        let truth = f.true_value().unwrap();
        assert!(((out.integral - truth) / truth).abs() < 5e-3);
    }

    #[test]
    fn seed_reproducibility() {
        let f = by_name("f3", 3).unwrap();
        let a = integrate(&*f, &cfg(1 << 13, 1e-3)).unwrap();
        let b = integrate(&*f, &cfg(1 << 13, 1e-3)).unwrap();
        assert_eq!(a.integral, b.integral);
        assert_eq!(a.sigma, b.sigma);
    }

    #[test]
    fn observer_sees_every_iteration() {
        let f = by_name("f5", 4).unwrap();
        let mut c = cfg(1 << 12, 1e-12);
        c.itmax = 5;
        c.ita = 3;
        c.skip = 0;
        let mut seen: Vec<(usize, bool, bool)> = Vec::new();
        let mut cb = |ev: &IterationEvent| {
            assert!(ev.grid.validate().is_ok());
            seen.push((ev.iteration, ev.adjusting, ev.converged));
        };
        let out = integrate_native_core(&*f, &c, None, Some(&mut cb))
            .unwrap()
            .output;
        assert_eq!(seen.len(), out.iterations);
        for (i, &(it, adjusting, _)) in seen.iter().enumerate() {
            assert_eq!(it, i);
            assert_eq!(adjusting, i < c.ita);
        }
        assert!(!seen.last().unwrap().2, "tau 1e-12 must not converge");
    }

    #[test]
    fn warm_start_reuses_grid_shape() {
        let f = by_name("f4", 5).unwrap();
        let donor = integrate_native_core(&*f, &cfg(1 << 13, 1e-3), None, None).unwrap();
        // Mismatched nb must be rejected with a clear error.
        let mut c = cfg(1 << 13, 1e-3);
        c.nb = 32;
        let err = integrate_native_core(&*f, &c, Some(&donor.grid), None)
            .unwrap_err()
            .to_string();
        assert!(err.contains("warm-start"), "{err}");
        // Mismatched grid mode is rejected too (no silent override).
        let mut c_mode = cfg(1 << 13, 1e-3);
        c_mode.grid_mode = GridMode::Shared1D;
        let err = integrate_native_core(&*f, &c_mode, Some(&donor.grid), None)
            .unwrap_err()
            .to_string();
        assert!(err.contains("grid mode"), "{err}");
        // Matching shape is accepted.
        let warm = integrate_native_core(&*f, &cfg(1 << 13, 1e-3), Some(&donor.grid), None);
        assert!(warm.is_ok());
    }

    #[test]
    fn vegas_plus_converges_and_is_honest() {
        let f = by_name("f4", 5).unwrap();
        let mut c = cfg(1 << 16, 1e-3);
        c.itmax = 20;
        c.ita = 12;
        c.seed = 5;
        c.threads = 2;
        c.sampling = Sampling::vegas_plus();
        let out = integrate(&*f, &c).unwrap();
        assert!(out.converged, "{out:?}");
        assert_eq!(out.backend, "native-vegas+");
        let truth = f.true_value().unwrap();
        assert!(
            (out.integral - truth).abs() < 4.0 * out.sigma,
            "I={} truth={truth} sigma={}",
            out.integral,
            out.sigma
        );
    }

    #[test]
    fn vegas_plus_beta_zero_bitwise_matches_uniform() {
        // beta = 0 degenerates to the exact uniform split, and both
        // engines share the fixed-task reduction — whole runs agree
        // bit for bit, importance-grid evolution included.
        let f = by_name("f3", 3).unwrap();
        let mut c = cfg(1 << 13, 1e-3);
        c.itmax = 8;
        c.ita = 5;
        let uni = integrate(&*f, &c).unwrap();
        c.sampling = Sampling::VegasPlus { beta: 0.0 };
        let vp = integrate(&*f, &c).unwrap();
        assert_eq!(uni.integral.to_bits(), vp.integral.to_bits());
        assert_eq!(uni.sigma.to_bits(), vp.sigma.to_bits());
        assert_eq!(uni.iterations, vp.iterations);
    }

    #[test]
    fn vegas_plus_bitwise_across_thread_counts() {
        let f = by_name("f4", 5).unwrap();
        let run = |threads: usize| {
            let mut c = cfg(4096, 1e-15); // fixed work: run all iterations
            c.itmax = 6;
            c.ita = 4;
            c.skip = 0;
            c.threads = threads;
            c.sampling = Sampling::vegas_plus();
            integrate(&*f, &c).unwrap()
        };
        let a = run(1);
        let b = run(4);
        assert_eq!(a.integral.to_bits(), b.integral.to_bits());
        assert_eq!(a.sigma.to_bits(), b.sigma.to_bits());
        assert_eq!(a.iterations, b.iterations);
    }

    #[test]
    fn vegas_plus_not_worse_than_uniform_on_peaked_integrand() {
        // Same per-iteration budget, fixed iteration count: adaptive
        // allocation should reach a comparable-or-smaller combined
        // sigma on a sharply peaked integrand.
        let f = by_name("f4", 5).unwrap();
        let mk = |sampling: Sampling| {
            let mut c = cfg(4096, 1e-15);
            c.itmax = 10;
            c.ita = 8;
            c.seed = 5;
            c.threads = 2;
            c.sampling = sampling;
            integrate(&*f, &c).unwrap()
        };
        let uni = mk(Sampling::Uniform);
        let vp = mk(Sampling::vegas_plus());
        assert_eq!(uni.calls_used, vp.calls_used, "same budget per iteration");
        assert!(
            vp.sigma < uni.sigma * 1.05,
            "vegas+ {} should be <= ~uniform {}",
            vp.sigma,
            uni.sigma
        );
    }

    #[test]
    fn vegas_plus_invalid_beta_rejected() {
        let f = by_name("f3", 3).unwrap();
        for beta in [-0.5, 1.5, f64::NAN] {
            let mut c = cfg(1 << 12, 1e-3);
            c.sampling = Sampling::VegasPlus { beta };
            let err = integrate(&*f, &c).unwrap_err().to_string();
            assert!(err.contains("beta"), "{err}");
        }
    }

    #[test]
    fn vegas_plus_exports_and_resumes_allocation() {
        // f4 d=5 at 4096 calls: g=4, m=1024, p=4 — enough per-cube
        // headroom (p > 2) for the allocation to actually move.
        let f = by_name("f4", 5).unwrap();
        let mut c = cfg(4096, 1e-15);
        c.itmax = 6;
        c.ita = 4;
        c.skip = 0;
        c.sampling = Sampling::vegas_plus();
        let donor = integrate_native_core(&*f, &c, None, None).unwrap();
        let layout = Layout::compute(5, 4096, c.nb, c.nblocks).unwrap();
        let snap = donor.grid.strat().expect("strat snapshot").clone();
        assert_eq!(snap.beta, 0.75);
        assert_eq!(snap.counts.len(), layout.m);
        assert_eq!(
            snap.counts.iter().map(|&x| x as usize).sum::<usize>(),
            layout.calls()
        );
        assert!(
            snap.counts.iter().any(|&x| x as usize != layout.p),
            "adaptive allocation never moved off the uniform split"
        );

        // Same layout: the snapshot resumes (first iteration samples
        // through the imported counts, so outputs differ from a fresh
        // uniform start).
        let resumed = integrate_native_core(&*f, &c, Some(&donor.grid), None).unwrap();
        assert!(resumed.grid.strat().is_some());
        let fresh_grid = donor.grid.clone().without_strat();
        let fresh = integrate_native_core(&*f, &c, Some(&fresh_grid), None).unwrap();
        assert_ne!(
            resumed.output.integral.to_bits(),
            fresh.output.integral.to_bits(),
            "resumed allocation must change the sample stream"
        );

        // Different budget (different m): grid warm-starts, allocation
        // silently refreshes to uniform for the new layout.
        let mut c2 = c.clone();
        c2.maxcalls = 1 << 13;
        let refreshed = integrate_native_core(&*f, &c2, Some(&donor.grid), None).unwrap();
        assert_eq!(refreshed.output.iterations, c2.itmax);
    }

    #[test]
    fn uniform_runs_carry_no_strat_state_and_no_alloc_events() {
        let f = by_name("f5", 4).unwrap();
        let mut c = cfg(1 << 12, 1e-3);
        c.itmax = 4;
        c.ita = 2;
        c.skip = 0;
        c.tau_rel = 1e-15;
        let mut allocs = Vec::new();
        let mut cb = |ev: &IterationEvent| allocs.push(ev.alloc);
        let out = integrate_native_core(&*f, &c, None, Some(&mut cb)).unwrap();
        assert!(out.grid.strat().is_none());
        assert!(allocs.iter().all(|a| a.is_none()));

        c.sampling = Sampling::vegas_plus();
        let mut allocs = Vec::new();
        let mut cb = |ev: &IterationEvent| allocs.push(ev.alloc);
        let out = integrate_native_core(&*f, &c, None, Some(&mut cb)).unwrap();
        assert!(out.grid.strat().is_some());
        assert_eq!(allocs.len(), out.output.iterations);
        for a in allocs {
            let a = a.expect("vegas+ iterations expose allocation stats");
            assert!(a.min >= 2);
            assert!(a.max >= a.min);
            assert!(a.total > 0);
        }
    }

    /// The one sanctioned `allow(deprecated)`: the test that pins the
    /// legacy shims to the facade core. Every other caller is migrated;
    /// `--no-default-features` drops the shims (and this module).
    #[cfg(feature = "legacy-api")]
    #[allow(deprecated)]
    mod legacy_shims {
        use super::super::{integrate_native, run_driver_traced, BorrowedNative};
        use super::{cfg, integrate};
        use crate::integrands::by_name;
        use crate::strat::Layout;

        #[test]
        fn deprecated_shims_still_delegate() {
            let f = by_name("f3", 3).unwrap();
            let c = cfg(1 << 12, 1e-3);
            let new = integrate(&*f, &c).unwrap();
            let old = integrate_native(&*f, &c).unwrap();
            assert_eq!(new.integral, old.integral);
            assert_eq!(new.sigma, old.sigma);
            let (traced, trace) = {
                let layout = Layout::compute(3, c.maxcalls, c.nb, c.nblocks).unwrap();
                let backend = BorrowedNative {
                    f: &*f,
                    layout,
                    threads: c.threads,
                };
                run_driver_traced(&backend, &c).unwrap()
            };
            assert_eq!(traced.integral, new.integral);
            assert_eq!(trace.iteration_estimates.len(), traced.iterations);
        }
    }
}
