//! Multi-job throughput scheduler: many resumable [`Session`]s
//! multiplexed round-robin over a shared worker pool — the serving
//! shell around the m-Cubes driver (exercised end-to-end by
//! `examples/service_demo.rs`).
//!
//! Where a naive service would run each job start-to-finish on
//! whichever worker picked it up, the [`Scheduler`] slices: a worker
//! steps a job's session until the job has consumed `calls_budget`
//! integrand evaluations in this slice, then requeues it behind its
//! priority peers and picks up the next job. Because sessions are
//! pull-based and `Send`, a job may migrate between workers mid-run —
//! and because the engine's reduction is bitwise
//! thread-count-invariant, its numbers never change when it does.
//!
//! * **Priorities** — higher [`JobRequest::priority`] jobs are always
//!   picked first; round-robin applies within a priority class.
//! * **Fairness** — `calls_budget` caps how many integrand
//!   evaluations one job may consume per scheduling slice, so one
//!   huge integral cannot starve a queue of small ones.
//! * **Streaming** — results arrive in *completion* order through
//!   [`Scheduler::stream`] (an iterator) or
//!   [`Scheduler::drain_with`] (a callback); [`Scheduler::drain`]
//!   keeps the old collect-everything API.
//! * **Isolation** — a panicking integrand fails only its own job;
//!   the worker, the queue, and every other job survive.
//!
//! Jobs are described by `api::IntegrandSpec`, so the scheduler
//! accepts registry names *and* user-supplied closures, and may carry
//! an `api::GridState` warm start.

// Narrowing / float→int casts in this file are deliberate and
// audited by `cargo xtask lint` (MC001); see docs/invariants.md.
#![allow(clippy::cast_possible_truncation)]

use super::driver::{IntegrationOutput, JobConfig};
use crate::api::{Checkpoint, GridState, IntegrandSpec, Session, StopReason};
use crate::error::{Error, Result};
use crate::integrands::IntegrandRef;
use crate::shard::ShardStats;
use crate::util::benchkit::percentile_sorted;
use std::cmp::Reverse;
use std::collections::{BTreeMap, VecDeque};
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::{self, JoinHandle};
use std::time::Instant;

/// Default fairness quantum: integrand evaluations one job may consume
/// per scheduling slice (~8 default-budget iterations).
pub const DEFAULT_CALLS_BUDGET: usize = 1 << 20;

/// A queued integration request.
///
/// `#[non_exhaustive]`: construct via [`JobRequest::registry`] /
/// [`JobRequest::custom`] and the `with_*` builders.
#[derive(Debug, Clone)]
#[non_exhaustive]
pub struct JobRequest {
    pub id: u64,
    /// What to integrate: registry name or custom integrand.
    pub spec: IntegrandSpec,
    pub config: JobConfig,
    /// Optional adapted grid from a previous run (same d, nb).
    pub warm_start: Option<GridState>,
    /// Scheduling priority: higher runs first (default 0).
    pub priority: i32,
}

impl JobRequest {
    /// A registry-integrand job.
    pub fn registry(id: u64, name: impl Into<String>, dim: usize, config: JobConfig) -> JobRequest {
        JobRequest {
            id,
            spec: IntegrandSpec::registry(name, dim),
            config,
            warm_start: None,
            priority: 0,
        }
    }

    /// A custom-integrand job (closures via `api::FnIntegrand`).
    pub fn custom(id: u64, f: IntegrandRef, config: JobConfig) -> JobRequest {
        JobRequest {
            id,
            spec: IntegrandSpec::custom(f),
            config,
            warm_start: None,
            priority: 0,
        }
    }

    /// Attach a warm-start grid.
    pub fn with_warm_start(mut self, grid: GridState) -> JobRequest {
        self.warm_start = Some(grid);
        self
    }

    /// Set the scheduling priority (higher runs first; default 0).
    pub fn with_priority(mut self, priority: i32) -> JobRequest {
        self.priority = priority;
        self
    }
}

/// The completed job with timing metadata.
///
/// `#[non_exhaustive]`: constructed only by the scheduler.
#[derive(Debug, Clone)]
#[non_exhaustive]
pub struct JobResult {
    pub id: u64,
    /// Display label of the integrand (registry or custom name).
    pub integrand: String,
    pub dim: usize,
    pub outcome: std::result::Result<IntegrationOutput, String>,
    /// Adapted grid after the run (successful jobs only) — feed it to a
    /// follow-up request's `warm_start`.
    pub grid: Option<GridState>,
    /// Why the run ended (successful jobs only).
    pub stop: Option<StopReason>,
    /// Seconds spent queued before a worker first picked the job up.
    pub queue_time: f64,
    /// End-to-end latency (enqueue -> completion), seconds.
    pub latency: f64,
    /// Scheduling slices the job took (> 1 means it was time-sliced
    /// against the `calls_budget` fairness cap).
    pub slices: usize,
    /// Shard-execution accounting (all-zero when the job ran on the
    /// ordinary single-worker backends).
    pub shard_stats: ShardStats,
}

/// Aggregate scheduler metrics.
#[derive(Debug, Clone)]
#[non_exhaustive]
pub struct ServiceMetrics {
    pub jobs: usize,
    pub failures: usize,
    pub wall_time: f64,
    /// Completed jobs per second of wall time.
    pub throughput: f64,
    /// Total integrand evaluations consumed by every scheduling slice
    /// so far — recorded slice-by-slice on a shared counter, so the
    /// figure is monotone across `metrics()` calls and counts work done
    /// by still-running and failed jobs, not just completed ones.
    pub total_calls: usize,
    /// Integrand evaluations per second of wall time (same monotone
    /// slice-level accounting as `total_calls`).
    pub calls_per_sec: f64,
    pub latency_p50: f64,
    pub latency_p95: f64,
    pub latency_max: f64,
    pub mean_queue_time: f64,
    /// Largest effective shard count any completed job ran with
    /// (0 when no job used the sharded backend).
    pub shards: usize,
    /// Total wall-clock milliseconds completed jobs spent merging
    /// shard partials.
    pub merge_ms: f64,
    /// Shard spans recovered through the coordinator's straggler path
    /// across completed jobs.
    pub straggler_retries: usize,
}

/// One job's life on the run queue.
struct QueuedJob {
    id: u64,
    priority: i32,
    label: String,
    dim: usize,
    enqueued: Instant,
    queue_time: Option<f64>,
    slices: usize,
    state: JobState,
}

enum JobState {
    /// Not yet started; the session is built on first pickup so spec
    /// resolution and config validation fail as job errors, not
    /// scheduler errors.
    Pending {
        spec: IntegrandSpec,
        cfg: JobConfig,
        warm: Option<GridState>,
    },
    Running(Box<Session>),
    /// Transient placeholder while the session is consumed by
    /// `finish()`.
    Taken,
}

/// What one scheduling slice concluded.
enum SliceResult {
    /// Budget spent, job still running: requeue it.
    Yield,
    /// Job completed (or failed): ship the result.
    Done(JobResult),
}

struct QueueState {
    /// Run queue: highest priority first (BTreeMap ascending over
    /// `Reverse(priority)`), round-robin within a priority class.
    buckets: BTreeMap<Reverse<i32>, VecDeque<QueuedJob>>,
    /// No further submissions; workers exit once idle and empty.
    closed: bool,
    /// Jobs currently held by workers (possibly to be requeued).
    in_flight: usize,
}

struct Shared {
    state: Mutex<QueueState>,
    cv: Condvar,
    calls_budget: AtomicUsize,
    /// Integrand evaluations recorded at the end of every scheduling
    /// slice — the monotone source for `ServiceMetrics::calls_per_sec`
    /// (completion-time accounting would drop in-flight and failed
    /// jobs, making the rate jumpy and non-monotone).
    calls_done: AtomicUsize,
}

/// The multi-job throughput scheduler (see the module docs).
pub struct Scheduler {
    shared: Arc<Shared>,
    workers: Vec<JoinHandle<()>>,
    /// `Some` until `stream()` hands the receiver over.
    rx: Option<Receiver<JobResult>>,
    submitted: usize,
    started: Instant,
}

impl Scheduler {
    /// Spawn a scheduler with `workers` native-engine workers.
    ///
    /// Each job runs single-threaded internally (`config.threads` is
    /// overridden to 1) so throughput scales with the worker count —
    /// the batching strategy the paper's uniform-workload argument
    /// suggests for many concurrent integrals.
    pub fn new(workers: usize) -> Scheduler {
        let shared = Arc::new(Shared {
            state: Mutex::new(QueueState {
                buckets: BTreeMap::new(),
                closed: false,
                in_flight: 0,
            }),
            cv: Condvar::new(),
            calls_budget: AtomicUsize::new(DEFAULT_CALLS_BUDGET),
            calls_done: AtomicUsize::new(0),
        });
        let (tx, rx) = channel();
        let mut handles = Vec::with_capacity(workers.max(1));
        for i in 0..workers.max(1) {
            let shared = Arc::clone(&shared);
            let tx: Sender<JobResult> = tx.clone();
            handles.push(
                thread::Builder::new()
                    .name(format!("mcubes-sched-{i}"))
                    .spawn(move || worker_loop(&shared, &tx))
                    // lint:allow(MC005, thread-spawn failure is unrecoverable resource exhaustion; abort with context)
                    .expect("spawn scheduler worker"),
            );
        }
        // Workers hold the only senders; `rx` drains until they exit.
        drop(tx);
        Scheduler {
            shared,
            workers: handles,
            rx: Some(rx),
            submitted: 0,
            started: Instant::now(),
        }
    }

    /// Set the fairness quantum: integrand evaluations one job may
    /// consume per scheduling slice (default
    /// [`DEFAULT_CALLS_BUDGET`]). Applies to slices started after the
    /// call.
    pub fn calls_budget(&mut self, calls: usize) {
        self.shared
            .calls_budget
            .store(calls.max(1), Ordering::Relaxed);
    }

    /// Enqueue one job.
    pub fn submit(&mut self, req: JobRequest) {
        self.submitted += 1;
        let job = QueuedJob {
            id: req.id,
            priority: req.priority,
            label: req.spec.label(),
            dim: req.spec.dim(),
            enqueued: Instant::now(),
            queue_time: None,
            slices: 0,
            state: JobState::Pending {
                spec: req.spec,
                cfg: req.config,
                warm: req.warm_start,
            },
        };
        {
            let mut q = self.shared.state.lock().unwrap();
            q.buckets
                .entry(Reverse(job.priority))
                .or_default()
                .push_back(job);
        }
        self.shared.cv.notify_one();
    }

    /// Close the queue and stream results in **completion order**.
    pub fn stream(mut self) -> ResultStream {
        {
            let mut q = self.shared.state.lock().unwrap();
            q.closed = true;
        }
        self.shared.cv.notify_all();
        ResultStream {
            // lint:allow(MC005, stream() consumes self — take() can only run once per Scheduler)
            rx: self.rx.take().expect("receiver present until stream()"),
            shared: Arc::clone(&self.shared),
            workers: std::mem::take(&mut self.workers),
            total: self.submitted,
            remaining: self.submitted,
            started: self.started,
            completed_at: None,
            latencies: Vec::with_capacity(self.submitted),
            queue_times: Vec::with_capacity(self.submitted),
            failures: 0,
            shard: ShardStats::default(),
        }
    }

    /// Wait for all submitted jobs, calling `cb` with each result as
    /// it completes, then return every result (sorted by id) plus
    /// metrics.
    pub fn drain_with(
        self,
        mut cb: impl FnMut(&JobResult),
    ) -> Result<(Vec<JobResult>, ServiceMetrics)> {
        let mut stream = self.stream();
        let mut results = Vec::with_capacity(stream.total);
        for r in stream.by_ref() {
            cb(&r);
            results.push(r);
        }
        if results.len() != stream.total {
            return Err(Error::Runtime("worker channel closed early".into()));
        }
        let metrics = stream.metrics();
        results.sort_by_key(|r| r.id);
        Ok((results, metrics))
    }

    /// Wait for all submitted jobs and compute metrics.
    pub fn drain(self) -> Result<(Vec<JobResult>, ServiceMetrics)> {
        self.drain_with(|_| {})
    }
}

impl Drop for Scheduler {
    fn drop(&mut self) {
        {
            let mut q = self.shared.state.lock().unwrap();
            q.closed = true;
        }
        self.shared.cv.notify_all();
        for h in self.workers.drain(..) {
            let _ = h.join();
        }
    }
}

/// Streaming results iterator (completion order). Workers are joined
/// once the stream is exhausted or dropped.
pub struct ResultStream {
    rx: Receiver<JobResult>,
    shared: Arc<Shared>,
    workers: Vec<JoinHandle<()>>,
    total: usize,
    remaining: usize,
    started: Instant,
    completed_at: Option<Instant>,
    latencies: Vec<f64>,
    queue_times: Vec<f64>,
    failures: usize,
    shard: ShardStats,
}

impl ResultStream {
    /// Jobs submitted before the stream was opened.
    pub fn total(&self) -> usize {
        self.total
    }

    /// Aggregate metrics over the results yielded so far (complete
    /// once the iterator is exhausted).
    pub fn metrics(&self) -> ServiceMetrics {
        let wall_time = self
            .completed_at
            .unwrap_or_else(Instant::now)
            .duration_since(self.started)
            .as_secs_f64();
        let mut latencies = self.latencies.clone();
        // total_cmp: a NaN timing (clock weirdness) must not panic the
        // whole drain; NaNs sort to the end and surface in latency_max.
        latencies.sort_by(f64::total_cmp);
        let jobs = latencies.len();
        let total_calls = self.shared.calls_done.load(Ordering::Relaxed);
        ServiceMetrics {
            jobs,
            failures: self.failures,
            wall_time,
            throughput: jobs as f64 / wall_time.max(1e-9),
            total_calls,
            calls_per_sec: total_calls as f64 / wall_time.max(1e-9),
            latency_p50: percentile_sorted(&latencies, 50.0),
            latency_p95: percentile_sorted(&latencies, 95.0),
            latency_max: latencies.last().copied().unwrap_or(0.0),
            mean_queue_time: self.queue_times.iter().sum::<f64>()
                / self.queue_times.len().max(1) as f64,
            shards: self.shard.shards,
            merge_ms: self.shard.merge_ms,
            straggler_retries: self.shard.straggler_retries,
        }
    }

    fn join_workers(&mut self) {
        for h in self.workers.drain(..) {
            let _ = h.join();
        }
    }
}

impl Iterator for ResultStream {
    type Item = JobResult;

    fn next(&mut self) -> Option<JobResult> {
        if self.remaining == 0 {
            self.join_workers();
            return None;
        }
        match self.rx.recv() {
            Ok(r) => {
                self.remaining -= 1;
                if self.remaining == 0 {
                    self.completed_at = Some(Instant::now());
                }
                self.latencies.push(r.latency);
                self.queue_times.push(r.queue_time);
                self.shard.absorb(r.shard_stats);
                if r.outcome.is_err() {
                    self.failures += 1;
                }
                Some(r)
            }
            Err(_) => {
                // Every worker exited with results outstanding — a
                // scheduler bug; end the stream so callers can notice
                // the shortfall against `total()`.
                self.remaining = 0;
                self.join_workers();
                None
            }
        }
    }
}

impl Drop for ResultStream {
    fn drop(&mut self) {
        self.join_workers();
    }
}

fn worker_loop(shared: &Shared, tx: &Sender<JobResult>) {
    loop {
        let mut job = {
            let mut q = shared.state.lock().unwrap();
            loop {
                if let Some(job) = pop_next(&mut q) {
                    q.in_flight += 1;
                    break job;
                }
                if q.closed && q.in_flight == 0 {
                    return;
                }
                // lint:allow(MC005, condvar poisoning mirrors lock poisoning — another worker already panicked while holding the queue; propagate the abort)
                q = shared.cv.wait(q).unwrap();
            }
        };
        let budget = shared.calls_budget.load(Ordering::Relaxed);
        // User-supplied closures can panic; isolate the panic to this
        // job so the batch (and the worker) survives and the stream
        // still yields every result.
        let slice = catch_unwind(AssertUnwindSafe(|| {
            run_slice(&mut job, budget, &shared.calls_done)
        }));
        match slice {
            Ok(SliceResult::Yield) => {
                {
                    let mut q = shared.state.lock().unwrap();
                    q.in_flight -= 1;
                    q.buckets
                        .entry(Reverse(job.priority))
                        .or_default()
                        .push_back(job);
                }
                shared.cv.notify_one();
            }
            Ok(SliceResult::Done(result)) => {
                let _ = tx.send(result);
                {
                    let mut q = shared.state.lock().unwrap();
                    q.in_flight -= 1;
                }
                shared.cv.notify_all();
            }
            Err(payload) => {
                let msg = payload
                    .downcast_ref::<&str>()
                    .map(|s| s.to_string())
                    .or_else(|| payload.downcast_ref::<String>().cloned())
                    .unwrap_or_else(|| "unknown panic payload".to_string());
                let _ = tx.send(job_result(
                    &job,
                    Err(format!("integrand panicked: {msg}")),
                    None,
                    None,
                ));
                {
                    let mut q = shared.state.lock().unwrap();
                    q.in_flight -= 1;
                }
                shared.cv.notify_all();
            }
        }
    }
}

fn pop_next(q: &mut QueueState) -> Option<QueuedJob> {
    let mut bucket = q.buckets.first_entry()?;
    let job = bucket.get_mut().pop_front();
    if bucket.get().is_empty() {
        bucket.remove();
    }
    job
}

fn job_result(
    job: &QueuedJob,
    outcome: std::result::Result<IntegrationOutput, String>,
    grid: Option<GridState>,
    stop: Option<StopReason>,
) -> JobResult {
    JobResult {
        id: job.id,
        integrand: job.label.clone(),
        dim: job.dim,
        outcome,
        grid,
        stop,
        queue_time: job.queue_time.unwrap_or(0.0),
        latency: job.enqueued.elapsed().as_secs_f64(),
        slices: job.slices,
        shard_stats: ShardStats::default(),
    }
}

/// Step one job's session until it finishes or spends `budget`
/// integrand evaluations in this slice. Evaluations consumed by the
/// slice are recorded on `calls_done` before it returns.
fn run_slice(job: &mut QueuedJob, budget: usize, calls_done: &AtomicUsize) -> SliceResult {
    job.slices += 1;
    if job.queue_time.is_none() {
        job.queue_time = Some(job.enqueued.elapsed().as_secs_f64());
    }
    if let JobState::Pending { spec, cfg, warm } = &job.state {
        let mut cfg = cfg.clone();
        cfg.threads = 1;
        let built = spec.resolve().and_then(|f| match warm {
            Some(grid) => Session::resume(f, cfg, &Checkpoint::from_grid(grid.clone())),
            None => Session::new(f, cfg),
        });
        match built {
            Ok(session) => job.state = JobState::Running(Box::new(session)),
            Err(e) => return SliceResult::Done(job_result(job, Err(e.to_string()), None, None)),
        }
    }
    // Step inside an inner scope so the session borrow provably ends
    // before the job's result is assembled.
    enum StepEnd {
        Finished,
        Yielded,
        Failed(String),
    }
    let end = match &mut job.state {
        JobState::Running(session) => {
            let slice_start = session.calls_used();
            let end = loop {
                match session.step() {
                    Err(e) => break StepEnd::Failed(e.to_string()),
                    Ok(None) => break StepEnd::Finished,
                    Ok(Some(_)) => {
                        if session.is_finished() {
                            break StepEnd::Finished;
                        }
                        if session.calls_used() - slice_start >= budget {
                            break StepEnd::Yielded;
                        }
                    }
                }
            };
            calls_done.fetch_add(session.calls_used() - slice_start, Ordering::Relaxed);
            end
        }
        _ => StepEnd::Failed("scheduler invariant violated: job state lost".into()),
    };
    match end {
        StepEnd::Yielded => SliceResult::Yield,
        StepEnd::Failed(msg) => SliceResult::Done(job_result(job, Err(msg), None, None)),
        StepEnd::Finished => {
            let JobState::Running(session) = std::mem::replace(&mut job.state, JobState::Taken)
            else {
                return SliceResult::Done(job_result(
                    job,
                    Err("scheduler invariant violated: job state lost".into()),
                    None,
                    None,
                ));
            };
            let shard_stats = session.shard_stats();
            match session.finish() {
                Ok(o) => {
                    let mut r = job_result(job, Ok(o.output), Some(o.grid), Some(o.stop));
                    r.shard_stats = shard_stats;
                    SliceResult::Done(r)
                }
                Err(e) => SliceResult::Done(job_result(job, Err(e.to_string()), None, None)),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::api::{FnIntegrand, RunPlan};

    fn quick_cfg() -> JobConfig {
        JobConfig {
            maxcalls: 1 << 12,
            plan: RunPlan::classic(8, 6, 1),
            tau_rel: 5e-3,
            ..Default::default()
        }
    }

    #[test]
    fn runs_batch_of_jobs() {
        let mut svc = Scheduler::new(4);
        for i in 0..12u64 {
            let mut cfg = quick_cfg();
            cfg.seed = 100 + i as u32;
            svc.submit(JobRequest::registry(i, "f5", 4, cfg));
        }
        let (results, metrics) = svc.drain().unwrap();
        assert_eq!(results.len(), 12);
        assert_eq!(metrics.jobs, 12);
        assert_eq!(metrics.failures, 0);
        assert!(metrics.throughput > 0.0);
        assert!(metrics.total_calls > 0);
        assert!(metrics.calls_per_sec > 0.0);
        // ids come back sorted
        for (i, r) in results.iter().enumerate() {
            assert_eq!(r.id, i as u64);
            assert!(r.outcome.is_ok());
            assert!(r.grid.is_some(), "successful jobs return their grid");
            assert!(r.stop.is_some());
            assert!(r.slices >= 1);
        }
    }

    #[test]
    fn bad_integrand_reports_failure_not_panic() {
        let mut svc = Scheduler::new(2);
        svc.submit(JobRequest::registry(0, "nope", 3, quick_cfg()));
        svc.submit(JobRequest::registry(1, "f5", 3, quick_cfg()));
        let (results, metrics) = svc.drain().unwrap();
        assert_eq!(metrics.failures, 1);
        assert!(results[0].outcome.is_err());
        assert!(results[0].grid.is_none());
        assert!(results[1].outcome.is_ok());
    }

    #[test]
    fn latency_accounting_sane() {
        let mut svc = Scheduler::new(1);
        for i in 0..3 {
            svc.submit(JobRequest::registry(i, "f3", 3, quick_cfg()));
        }
        let (results, metrics) = svc.drain().unwrap();
        for r in &results {
            assert!(r.latency >= r.queue_time);
        }
        assert!(metrics.latency_p95 >= metrics.latency_p50);
        assert!(metrics.latency_max >= metrics.latency_p95);
    }

    #[test]
    fn custom_closure_jobs_run() {
        let mut svc = Scheduler::new(2);
        let f = FnIntegrand::unit(3, |x: &[f64]| x.iter().sum::<f64>())
            .named("sum3")
            .with_true_value(1.5)
            .into_ref();
        svc.submit(JobRequest::custom(0, f, quick_cfg()));
        let (results, metrics) = svc.drain().unwrap();
        assert_eq!(metrics.failures, 0);
        assert_eq!(results[0].integrand, "sum3");
        assert_eq!(results[0].dim, 3);
        let out = results[0].outcome.as_ref().unwrap();
        assert!((out.integral - 1.5).abs() < 0.05, "I = {}", out.integral);
    }

    #[test]
    fn panicking_closure_is_isolated_from_the_batch() {
        let mut svc = Scheduler::new(2);
        let bomb = FnIntegrand::unit(3, |x: &[f64]| {
            // Out-of-range index: panics on the first evaluation.
            x[7]
        })
        .named("bomb")
        .into_ref();
        svc.submit(JobRequest::custom(0, bomb, quick_cfg()));
        svc.submit(JobRequest::registry(1, "f3", 3, quick_cfg()));
        svc.submit(JobRequest::registry(2, "f5", 4, quick_cfg()));
        let (results, metrics) = svc.drain().unwrap();
        assert_eq!(results.len(), 3, "all results survive the panic");
        assert_eq!(metrics.failures, 1);
        let err = results[0].outcome.as_ref().unwrap_err();
        assert!(err.contains("panicked"), "{err}");
        assert!(results[1].outcome.is_ok());
        assert!(results[2].outcome.is_ok());
    }

    #[test]
    fn time_slicing_interleaves_and_preserves_results_bitwise() {
        // The same batch, run-to-completion vs finely sliced on one
        // worker: sessions are deterministic state machines, so the
        // numbers must agree bit for bit — slicing only changes the
        // schedule. The tiny quantum forces multiple slices per job.
        let batch = |svc: &mut Scheduler| {
            for i in 0..4u64 {
                let mut cfg = quick_cfg();
                cfg.tau_rel = 1e-12; // fixed work: run the whole plan
                cfg.seed = 500 + i as u32;
                svc.submit(JobRequest::registry(i, "f5", 4, cfg));
            }
        };
        let mut whole = Scheduler::new(1);
        whole.calls_budget(usize::MAX);
        batch(&mut whole);
        let (a, _) = whole.drain().unwrap();

        let mut sliced = Scheduler::new(1);
        sliced.calls_budget(1 << 12); // ~1 iteration per slice
        batch(&mut sliced);
        let (b, _) = sliced.drain().unwrap();

        for (ra, rb) in a.iter().zip(&b) {
            let (oa, ob) = (ra.outcome.as_ref().unwrap(), rb.outcome.as_ref().unwrap());
            assert_eq!(oa.integral.to_bits(), ob.integral.to_bits());
            assert_eq!(oa.sigma.to_bits(), ob.sigma.to_bits());
            assert_eq!(oa.iterations, ob.iterations);
            assert_eq!(ra.slices, 1, "uncapped jobs run in one slice");
            assert!(rb.slices > 1, "capped jobs must be time-sliced");
        }
    }

    #[test]
    fn worker_count_does_not_change_results() {
        let batch = |svc: &mut Scheduler| {
            for i in 0..6u64 {
                let mut cfg = quick_cfg();
                cfg.seed = 40 + i as u32;
                svc.submit(JobRequest::registry(i, "f4", 5, cfg));
            }
        };
        let mut s1 = Scheduler::new(1);
        batch(&mut s1);
        let (a, _) = s1.drain().unwrap();
        let mut s4 = Scheduler::new(4);
        s4.calls_budget(1 << 13);
        batch(&mut s4);
        let (b, _) = s4.drain().unwrap();
        for (ra, rb) in a.iter().zip(&b) {
            let (oa, ob) = (ra.outcome.as_ref().unwrap(), rb.outcome.as_ref().unwrap());
            assert_eq!(oa.integral.to_bits(), ob.integral.to_bits());
            assert_eq!(oa.sigma.to_bits(), ob.sigma.to_bits());
        }
    }

    #[test]
    fn priorities_order_the_queue() {
        // One worker, held busy by a chunky blocker while the rest of
        // the batch is enqueued; when it frees up, the high-priority
        // job must complete before the earlier-submitted low one.
        let mut svc = Scheduler::new(1);
        let mut blocker = quick_cfg();
        blocker.maxcalls = 1 << 16;
        blocker.tau_rel = 1e-12;
        blocker.plan = RunPlan::classic(10, 6, 0);
        svc.submit(JobRequest::registry(0, "f5", 6, blocker));
        svc.submit(JobRequest::registry(1, "f3", 3, quick_cfg()).with_priority(-5));
        svc.submit(JobRequest::registry(2, "f3", 3, quick_cfg()).with_priority(5));
        let order: std::sync::Mutex<Vec<u64>> = std::sync::Mutex::new(Vec::new());
        let (results, _) = svc
            .drain_with(|r| order.lock().unwrap().push(r.id))
            .unwrap();
        assert_eq!(results.len(), 3);
        let order = order.into_inner().unwrap();
        let hi = order.iter().position(|&id| id == 2).unwrap();
        let lo = order.iter().position(|&id| id == 1).unwrap();
        assert!(hi < lo, "priority 5 must complete before priority -5: {order:?}");
    }

    #[test]
    fn stream_yields_results_in_completion_order() {
        let mut svc = Scheduler::new(2);
        for i in 0..5u64 {
            let mut cfg = quick_cfg();
            cfg.seed = i as u32;
            svc.submit(JobRequest::registry(i, "f3", 3, cfg));
        }
        let mut stream = svc.stream();
        assert_eq!(stream.total(), 5);
        let results: Vec<JobResult> = stream.by_ref().collect();
        assert_eq!(results.len(), 5);
        let metrics = stream.metrics();
        assert_eq!(metrics.jobs, 5);
        assert_eq!(metrics.failures, 0);
    }

    #[test]
    fn sharded_jobs_surface_stats_and_match_unsharded_bitwise() {
        let run = |shards: usize| {
            let mut svc = Scheduler::new(2);
            let mut cfg = quick_cfg();
            cfg.tau_rel = 1e-12; // fixed work: run the whole plan
            cfg.shards = shards;
            svc.submit(JobRequest::registry(0, "f4", 5, cfg));
            svc.drain().unwrap()
        };
        let (a, ma) = run(1);
        let (b, mb) = run(8);
        let oa = a[0].outcome.as_ref().unwrap();
        let ob = b[0].outcome.as_ref().unwrap();
        assert_eq!(oa.integral.to_bits(), ob.integral.to_bits());
        assert_eq!(oa.sigma.to_bits(), ob.sigma.to_bits());
        assert_eq!(ma.shards, 0, "single-worker batch reports no shards");
        assert_eq!(mb.shards, 8, "sharded batch surfaces its shard count");
        assert_eq!(b[0].shard_stats.shards, 8);
        assert_eq!(mb.straggler_retries, 0, "in-process pool never straggles");
        // The slice-level counter must account for all completed work.
        assert!(ma.total_calls >= oa.calls_used);
        assert!(mb.total_calls >= ob.calls_used);
    }

    #[test]
    fn failed_jobs_still_count_their_calls() {
        // A custom integrand that panics during its second iteration:
        // completion-time accounting would report zero calls for it;
        // the slice-level counter must still show the first slice's
        // work (the tiny quantum makes each iteration its own slice).
        let mut svc = Scheduler::new(1);
        svc.calls_budget(1 << 10);
        let hits = std::sync::Arc::new(AtomicUsize::new(0));
        let h = std::sync::Arc::clone(&hits);
        let f = FnIntegrand::unit(3, move |x: &[f64]| {
            let n = h.fetch_add(1, Ordering::Relaxed);
            assert!(n < 5_000, "bomb");
            x[0]
        })
        .named("late-bomb")
        .into_ref();
        svc.submit(JobRequest::custom(0, f, quick_cfg()));
        let (results, metrics) = svc.drain().unwrap();
        assert_eq!(metrics.failures, 1);
        assert!(results[0].outcome.is_err());
        assert!(
            metrics.total_calls > 0,
            "calls burned before the failure must be visible"
        );
    }

    #[test]
    fn warm_started_job_reuses_donor_grid() {
        // Donor adapts a grid; a warm-started rerun of the same job
        // must converge at least as fast.
        let cold_cfg = JobConfig {
            maxcalls: 1 << 13,
            plan: RunPlan::classic(20, 12, 2),
            tau_rel: 5e-3,
            seed: 5,
            ..Default::default()
        };
        let mut svc = Scheduler::new(1);
        svc.submit(JobRequest::registry(0, "f4", 5, cold_cfg.clone()));
        let (results, _) = svc.drain().unwrap();
        let donor_grid = results[0].grid.clone().unwrap();
        let cold_iters = results[0].outcome.as_ref().unwrap().iterations;

        let mut warm_cfg = cold_cfg;
        warm_cfg.plan = RunPlan::classic(20, 0, 0);
        let mut svc = Scheduler::new(1);
        svc.submit(JobRequest::registry(1, "f4", 5, warm_cfg).with_warm_start(donor_grid));
        let (results, metrics) = svc.drain().unwrap();
        assert_eq!(metrics.failures, 0);
        let warm = results[0].outcome.as_ref().unwrap();
        assert!(warm.converged, "{warm:?}");
        assert!(
            warm.iterations <= cold_iters,
            "warm {} vs cold {cold_iters}",
            warm.iterations
        );
    }
}
