//! Integration job service: a leader queue + worker pool that runs
//! many integration jobs concurrently and reports latency/throughput —
//! the serving shell around the m-Cubes driver (exercised end-to-end by
//! `examples/service_demo.rs`).

use super::driver::{integrate_native, IntegrationOutput, JobConfig};
use crate::error::{Error, Result};
use crate::integrands::by_name;
use crate::util::benchkit::percentile_sorted;
use crate::util::threadpool::WorkerPool;
use std::sync::mpsc::{channel, Receiver, Sender};
 
use std::time::Instant;

/// A queued integration request.
#[derive(Debug, Clone)]
pub struct JobRequest {
    pub id: u64,
    pub integrand: String,
    pub dim: usize,
    pub config: JobConfig,
}

/// The completed job with timing metadata.
#[derive(Debug, Clone)]
pub struct JobResult {
    pub id: u64,
    pub integrand: String,
    pub dim: usize,
    pub outcome: std::result::Result<IntegrationOutput, String>,
    /// Seconds spent queued before a worker picked the job up.
    pub queue_time: f64,
    /// End-to-end latency (enqueue -> completion), seconds.
    pub latency: f64,
}

/// Aggregate service metrics.
#[derive(Debug, Clone)]
pub struct ServiceMetrics {
    pub jobs: usize,
    pub failures: usize,
    pub wall_time: f64,
    pub throughput: f64,
    pub latency_p50: f64,
    pub latency_p95: f64,
    pub latency_max: f64,
    pub mean_queue_time: f64,
}

/// The service: submit jobs, then `drain()` for results + metrics.
pub struct IntegrationService {
    pool: WorkerPool,
    tx: Sender<JobResult>,
    rx: Receiver<JobResult>,
    submitted: usize,
    started: Instant,
}

impl IntegrationService {
    /// Spawn a service with `workers` native-engine workers.
    ///
    /// Each job runs single-threaded internally (`config.threads` is
    /// overridden to 1) so throughput scales with the worker count —
    /// the batching strategy the paper's uniform-workload argument
    /// suggests for many concurrent integrals.
    pub fn new(workers: usize) -> IntegrationService {
        let (tx, rx) = channel();
        IntegrationService {
            pool: WorkerPool::new(workers),
            tx,
            rx,
            submitted: 0,
            started: Instant::now(),
        }
    }

    /// Enqueue one job.
    pub fn submit(&mut self, req: JobRequest) {
        let tx = self.tx.clone();
        let enqueued = Instant::now();
        self.submitted += 1;
        self.pool.submit(move || {
            let queue_time = enqueued.elapsed().as_secs_f64();
            let mut cfg = req.config.clone();
            cfg.threads = 1;
            let outcome = by_name(&req.integrand, req.dim)
                .and_then(|f| integrate_native(&*f, &cfg))
                .map_err(|e| e.to_string());
            let _ = tx.send(JobResult {
                id: req.id,
                integrand: req.integrand,
                dim: req.dim,
                outcome,
                queue_time,
                latency: enqueued.elapsed().as_secs_f64(),
            });
        });
    }

    /// Wait for all submitted jobs and compute metrics.
    pub fn drain(self) -> Result<(Vec<JobResult>, ServiceMetrics)> {
        let IntegrationService {
            pool,
            tx,
            rx,
            submitted,
            started,
        } = self;
        drop(tx); // our clone; workers hold theirs until done
        let mut results = Vec::with_capacity(submitted);
        for _ in 0..submitted {
            let r = rx
                .recv()
                .map_err(|_| Error::Runtime("worker channel closed early".into()))?;
            results.push(r);
        }
        pool.shutdown();
        let wall_time = started.elapsed().as_secs_f64();

        let mut latencies: Vec<f64> = results.iter().map(|r| r.latency).collect();
        latencies.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let failures = results.iter().filter(|r| r.outcome.is_err()).count();
        let metrics = ServiceMetrics {
            jobs: results.len(),
            failures,
            wall_time,
            throughput: results.len() as f64 / wall_time.max(1e-9),
            latency_p50: percentile_sorted(&latencies, 50.0),
            latency_p95: percentile_sorted(&latencies, 95.0),
            latency_max: latencies.last().copied().unwrap_or(0.0),
            mean_queue_time: results.iter().map(|r| r.queue_time).sum::<f64>()
                / results.len().max(1) as f64,
        };
        results.sort_by_key(|r| r.id);
        Ok((results, metrics))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn quick_cfg() -> JobConfig {
        JobConfig {
            maxcalls: 1 << 12,
            itmax: 8,
            ita: 6,
            skip: 1,
            tau_rel: 5e-3,
            ..Default::default()
        }
    }

    #[test]
    fn runs_batch_of_jobs() {
        let mut svc = IntegrationService::new(4);
        for i in 0..12u64 {
            svc.submit(JobRequest {
                id: i,
                integrand: "f5".into(),
                dim: 4,
                config: JobConfig {
                    seed: 100 + i as u32,
                    ..quick_cfg()
                },
            });
        }
        let (results, metrics) = svc.drain().unwrap();
        assert_eq!(results.len(), 12);
        assert_eq!(metrics.jobs, 12);
        assert_eq!(metrics.failures, 0);
        assert!(metrics.throughput > 0.0);
        // ids come back sorted
        for (i, r) in results.iter().enumerate() {
            assert_eq!(r.id, i as u64);
            assert!(r.outcome.is_ok());
        }
    }

    #[test]
    fn bad_integrand_reports_failure_not_panic() {
        let mut svc = IntegrationService::new(2);
        svc.submit(JobRequest {
            id: 0,
            integrand: "nope".into(),
            dim: 3,
            config: quick_cfg(),
        });
        svc.submit(JobRequest {
            id: 1,
            integrand: "f5".into(),
            dim: 3,
            config: quick_cfg(),
        });
        let (results, metrics) = svc.drain().unwrap();
        assert_eq!(metrics.failures, 1);
        assert!(results[0].outcome.is_err());
        assert!(results[1].outcome.is_ok());
    }

    #[test]
    fn latency_accounting_sane() {
        let mut svc = IntegrationService::new(1);
        for i in 0..3 {
            svc.submit(JobRequest {
                id: i,
                integrand: "f3".into(),
                dim: 3,
                config: quick_cfg(),
            });
        }
        let (results, metrics) = svc.drain().unwrap();
        for r in &results {
            assert!(r.latency >= r.queue_time);
        }
        assert!(metrics.latency_p95 >= metrics.latency_p50);
        assert!(metrics.latency_max >= metrics.latency_p95);
    }
}
