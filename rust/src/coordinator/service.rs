//! Integration job service: a leader queue + worker pool that runs
//! many integration jobs concurrently and reports latency/throughput —
//! the serving shell around the m-Cubes driver (exercised end-to-end by
//! `examples/service_demo.rs`).
//!
//! Jobs are described by `api::IntegrandSpec`, so the service accepts
//! registry names *and* user-supplied closures/`IntegrandRef`s, and may
//! carry an `api::GridState` warm start — repeated similar integrals
//! skip the importance-grid warm-up, and each result returns its
//! adapted grid for follow-up jobs.

use super::driver::{integrate_native_core, IntegrationOutput, JobConfig};
use crate::api::{GridState, IntegrandSpec};
use crate::error::{Error, Result};
use crate::integrands::IntegrandRef;
use crate::util::benchkit::percentile_sorted;
use crate::util::threadpool::WorkerPool;
use std::sync::mpsc::{channel, Receiver, Sender};
use std::time::Instant;

/// A queued integration request.
#[derive(Debug, Clone)]
pub struct JobRequest {
    pub id: u64,
    /// What to integrate: registry name or custom integrand.
    pub spec: IntegrandSpec,
    pub config: JobConfig,
    /// Optional adapted grid from a previous run (same d, nb).
    pub warm_start: Option<GridState>,
}

impl JobRequest {
    /// A registry-integrand job.
    pub fn registry(id: u64, name: impl Into<String>, dim: usize, config: JobConfig) -> JobRequest {
        JobRequest {
            id,
            spec: IntegrandSpec::registry(name, dim),
            config,
            warm_start: None,
        }
    }

    /// A custom-integrand job (closures via `api::FnIntegrand`).
    pub fn custom(id: u64, f: IntegrandRef, config: JobConfig) -> JobRequest {
        JobRequest {
            id,
            spec: IntegrandSpec::custom(f),
            config,
            warm_start: None,
        }
    }

    /// Attach a warm-start grid.
    pub fn with_warm_start(mut self, grid: GridState) -> JobRequest {
        self.warm_start = Some(grid);
        self
    }
}

/// The completed job with timing metadata.
#[derive(Debug, Clone)]
pub struct JobResult {
    pub id: u64,
    /// Display label of the integrand (registry or custom name).
    pub integrand: String,
    pub dim: usize,
    pub outcome: std::result::Result<IntegrationOutput, String>,
    /// Adapted grid after the run (successful jobs only) — feed it to a
    /// follow-up request's `warm_start`.
    pub grid: Option<GridState>,
    /// Seconds spent queued before a worker picked the job up.
    pub queue_time: f64,
    /// End-to-end latency (enqueue -> completion), seconds.
    pub latency: f64,
}

/// Aggregate service metrics.
#[derive(Debug, Clone)]
pub struct ServiceMetrics {
    pub jobs: usize,
    pub failures: usize,
    pub wall_time: f64,
    pub throughput: f64,
    pub latency_p50: f64,
    pub latency_p95: f64,
    pub latency_max: f64,
    pub mean_queue_time: f64,
}

/// The service: submit jobs, then `drain()` for results + metrics.
pub struct IntegrationService {
    pool: WorkerPool,
    tx: Sender<JobResult>,
    rx: Receiver<JobResult>,
    submitted: usize,
    started: Instant,
}

impl IntegrationService {
    /// Spawn a service with `workers` native-engine workers.
    ///
    /// Each job runs single-threaded internally (`config.threads` is
    /// overridden to 1) so throughput scales with the worker count —
    /// the batching strategy the paper's uniform-workload argument
    /// suggests for many concurrent integrals.
    pub fn new(workers: usize) -> IntegrationService {
        let (tx, rx) = channel();
        IntegrationService {
            pool: WorkerPool::new(workers),
            tx,
            rx,
            submitted: 0,
            started: Instant::now(),
        }
    }

    /// Enqueue one job.
    pub fn submit(&mut self, req: JobRequest) {
        let tx = self.tx.clone();
        let enqueued = Instant::now();
        self.submitted += 1;
        self.pool.submit(move || {
            let queue_time = enqueued.elapsed().as_secs_f64();
            let mut cfg = req.config.clone();
            cfg.threads = 1;
            let label = req.spec.label();
            let dim = req.spec.dim();
            // User-supplied closures can panic; isolate the panic to
            // this job so the batch (and the worker) survives and
            // drain() still returns every result.
            let run = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                req.spec
                    .resolve()
                    .and_then(|f| integrate_native_core(&*f, &cfg, req.warm_start.as_ref(), None))
            }));
            let (outcome, grid) = match run {
                Ok(Ok(o)) => (Ok(o.output), Some(o.grid)),
                Ok(Err(e)) => (Err(e.to_string()), None),
                Err(payload) => {
                    let msg = payload
                        .downcast_ref::<&str>()
                        .map(|s| s.to_string())
                        .or_else(|| payload.downcast_ref::<String>().cloned())
                        .unwrap_or_else(|| "unknown panic payload".to_string());
                    (Err(format!("integrand panicked: {msg}")), None)
                }
            };
            let _ = tx.send(JobResult {
                id: req.id,
                integrand: label,
                dim,
                outcome,
                grid,
                queue_time,
                latency: enqueued.elapsed().as_secs_f64(),
            });
        });
    }

    /// Wait for all submitted jobs and compute metrics.
    pub fn drain(self) -> Result<(Vec<JobResult>, ServiceMetrics)> {
        let IntegrationService {
            pool,
            tx,
            rx,
            submitted,
            started,
        } = self;
        drop(tx); // our clone; workers hold theirs until done
        let mut results = Vec::with_capacity(submitted);
        for _ in 0..submitted {
            let r = rx
                .recv()
                .map_err(|_| Error::Runtime("worker channel closed early".into()))?;
            results.push(r);
        }
        pool.shutdown();
        let wall_time = started.elapsed().as_secs_f64();

        let mut latencies: Vec<f64> = results.iter().map(|r| r.latency).collect();
        // total_cmp: a NaN timing (clock weirdness) must not panic the
        // whole drain; NaNs sort to the end and surface in latency_max.
        latencies.sort_by(f64::total_cmp);
        let failures = results.iter().filter(|r| r.outcome.is_err()).count();
        let metrics = ServiceMetrics {
            jobs: results.len(),
            failures,
            wall_time,
            throughput: results.len() as f64 / wall_time.max(1e-9),
            latency_p50: percentile_sorted(&latencies, 50.0),
            latency_p95: percentile_sorted(&latencies, 95.0),
            latency_max: latencies.last().copied().unwrap_or(0.0),
            mean_queue_time: results.iter().map(|r| r.queue_time).sum::<f64>()
                / results.len().max(1) as f64,
        };
        results.sort_by_key(|r| r.id);
        Ok((results, metrics))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::api::FnIntegrand;

    fn quick_cfg() -> JobConfig {
        JobConfig {
            maxcalls: 1 << 12,
            itmax: 8,
            ita: 6,
            skip: 1,
            tau_rel: 5e-3,
            ..Default::default()
        }
    }

    #[test]
    fn runs_batch_of_jobs() {
        let mut svc = IntegrationService::new(4);
        for i in 0..12u64 {
            svc.submit(JobRequest::registry(
                i,
                "f5",
                4,
                JobConfig {
                    seed: 100 + i as u32,
                    ..quick_cfg()
                },
            ));
        }
        let (results, metrics) = svc.drain().unwrap();
        assert_eq!(results.len(), 12);
        assert_eq!(metrics.jobs, 12);
        assert_eq!(metrics.failures, 0);
        assert!(metrics.throughput > 0.0);
        // ids come back sorted
        for (i, r) in results.iter().enumerate() {
            assert_eq!(r.id, i as u64);
            assert!(r.outcome.is_ok());
            assert!(r.grid.is_some(), "successful jobs return their grid");
        }
    }

    #[test]
    fn bad_integrand_reports_failure_not_panic() {
        let mut svc = IntegrationService::new(2);
        svc.submit(JobRequest::registry(0, "nope", 3, quick_cfg()));
        svc.submit(JobRequest::registry(1, "f5", 3, quick_cfg()));
        let (results, metrics) = svc.drain().unwrap();
        assert_eq!(metrics.failures, 1);
        assert!(results[0].outcome.is_err());
        assert!(results[0].grid.is_none());
        assert!(results[1].outcome.is_ok());
    }

    #[test]
    fn latency_accounting_sane() {
        let mut svc = IntegrationService::new(1);
        for i in 0..3 {
            svc.submit(JobRequest::registry(i, "f3", 3, quick_cfg()));
        }
        let (results, metrics) = svc.drain().unwrap();
        for r in &results {
            assert!(r.latency >= r.queue_time);
        }
        assert!(metrics.latency_p95 >= metrics.latency_p50);
        assert!(metrics.latency_max >= metrics.latency_p95);
    }

    #[test]
    fn custom_closure_jobs_run() {
        let mut svc = IntegrationService::new(2);
        let f = FnIntegrand::unit(3, |x: &[f64]| x.iter().sum::<f64>())
            .named("sum3")
            .with_true_value(1.5)
            .into_ref();
        svc.submit(JobRequest::custom(0, f, quick_cfg()));
        let (results, metrics) = svc.drain().unwrap();
        assert_eq!(metrics.failures, 0);
        assert_eq!(results[0].integrand, "sum3");
        assert_eq!(results[0].dim, 3);
        let out = results[0].outcome.as_ref().unwrap();
        assert!((out.integral - 1.5).abs() < 0.05, "I = {}", out.integral);
    }

    #[test]
    fn panicking_closure_is_isolated_from_the_batch() {
        let mut svc = IntegrationService::new(2);
        let bomb = FnIntegrand::unit(3, |x: &[f64]| {
            // Out-of-range index: panics on the first evaluation.
            x[7]
        })
        .named("bomb")
        .into_ref();
        svc.submit(JobRequest::custom(0, bomb, quick_cfg()));
        svc.submit(JobRequest::registry(1, "f3", 3, quick_cfg()));
        svc.submit(JobRequest::registry(2, "f5", 4, quick_cfg()));
        let (results, metrics) = svc.drain().unwrap();
        assert_eq!(results.len(), 3, "all results survive the panic");
        assert_eq!(metrics.failures, 1);
        let err = results[0].outcome.as_ref().unwrap_err();
        assert!(err.contains("panicked"), "{err}");
        assert!(results[1].outcome.is_ok());
        assert!(results[2].outcome.is_ok());
    }

    #[test]
    fn warm_started_job_reuses_donor_grid() {
        // Donor adapts a grid; a warm-started rerun of the same job
        // must converge at least as fast.
        let cold_cfg = JobConfig {
            maxcalls: 1 << 13,
            itmax: 20,
            ita: 12,
            skip: 2,
            tau_rel: 5e-3,
            seed: 5,
            ..Default::default()
        };
        let mut svc = IntegrationService::new(1);
        svc.submit(JobRequest::registry(0, "f4", 5, cold_cfg.clone()));
        let (results, _) = svc.drain().unwrap();
        let donor_grid = results[0].grid.clone().unwrap();
        let cold_iters = results[0].outcome.as_ref().unwrap().iterations;

        let warm_cfg = JobConfig {
            ita: 0,
            skip: 0,
            ..cold_cfg
        };
        let mut svc = IntegrationService::new(1);
        svc.submit(JobRequest::registry(1, "f4", 5, warm_cfg).with_warm_start(donor_grid));
        let (results, metrics) = svc.drain().unwrap();
        assert_eq!(metrics.failures, 0);
        let warm = results[0].outcome.as_ref().unwrap();
        assert!(warm.converged, "{warm:?}");
        assert!(
            warm.iterations <= cold_iters,
            "warm {} vs cold {cold_iters}",
            warm.iterations
        );
    }
}
