//! Execution backends for one V-Sample pass.
//!
//! The driver is backend-agnostic: `PjrtBackend` runs the AOT Pallas
//! artifact through PJRT (the paper's GPU kernel), [`EngineBackend`]
//! adapts any native [`Engine`] — uniform, VEGAS+ stratified, or a
//! custom impl — to the driver contract (the paper's Kokkos-style
//! second platform). Both draw identical Philox streams, so for the
//! same (seed, iteration) the results agree to summation-order
//! tolerance.
//!
//! Both backends are batch-first: the artifact evaluates whole
//! per-thread-block sample batches on device, and the native engines
//! mirror that with the shared fill-block → `Integrand::eval_batch` →
//! reduce walk ([`crate::engine::walk`]) over
//! [`crate::engine::PointBlock`]s — one virtual call per block, never
//! one per point.

use crate::api::StratSnapshot;
use crate::engine::{Engine, ExecPath, FillPath, UniformEngine, VSampleOpts, VegasPlusEngine};
use crate::error::Result;
use crate::estimator::IterationResult;
use crate::grid::Bins;
use crate::integrands::IntegrandRef;
use crate::runtime::{ArtifactMeta, PjrtRuntime, Registry, VSampleExecutable};
use crate::strat::{Bounds, Layout};
use std::sync::Arc;

/// One V-Sample pass provider.
pub trait VSampleBackend {
    /// Stratification layout (fixed per backend instance).
    fn layout(&self) -> Layout;
    /// Per-axis integration-box bounds.
    fn bounds(&self) -> Bounds;
    /// Backend label for reports ("pjrt" / "native" / "native-vegas+").
    fn name(&self) -> &'static str;
    /// Run one iteration; histogram returned only when `adjust`.
    ///
    /// `&mut self` because adaptive backends fold the pass's variance
    /// observations into their allocation state — the engines'
    /// [`Engine::update`] hook, which is what lets this layer carry no
    /// interior-mutability shims.
    fn run(
        &mut self,
        bins: &Bins,
        seed: u32,
        iteration: u32,
        adjust: bool,
    ) -> Result<(IterationResult, Option<Vec<f64>>)>;
    /// Per-cube allocation summary of the *most recent* `run` call —
    /// `Some` only for adaptively-stratified backends (VEGAS+). The
    /// driver forwards it to observers via `IterationEvent::alloc`.
    fn alloc_stats(&self) -> Option<crate::strat::AllocStats> {
        None
    }
    /// Export the live per-cube allocation state, when this backend is
    /// adaptively stratified — the session layer stores it in
    /// `GridState`/`Checkpoint` so warm starts and suspended runs
    /// resume the allocation bit-identically.
    fn strat_export(&self) -> Option<StratSnapshot> {
        None
    }
    /// Cumulative shard-execution accounting — `Some` only for the
    /// sharded backend ([`crate::shard::ShardedBackend`]). The session
    /// layer folds it across stages; the service layer surfaces it in
    /// `ServiceMetrics`.
    fn shard_stats(&self) -> Option<crate::shard::ShardStats> {
        None
    }
}

/// Driver adapter over any native [`Engine`] — the one backend that
/// replaced the historical `NativeBackend`/`StratifiedBackend` pair.
///
/// Generic plumbing only: the engine owns layout and allocation state;
/// this layer contributes the integrand handle, the thread count, the
/// [`ExecPath`] knob, and the "stats describe the allocation the pass
/// *ran with*" snapshot discipline (captured before the pass, since
/// the engine re-apportions inside [`Engine::vsample`]). Works
/// identically over a concrete engine type and over `Box<dyn Engine>`
/// — the dyn-dispatch golden tests pin that both produce the same
/// bits.
pub struct EngineBackend<E: Engine> {
    integrand: IntegrandRef,
    threads: usize,
    exec: ExecPath,
    engine: E,
    /// Allocation summary snapshot taken at the top of the most recent
    /// `run` — i.e. the allocation that pass sampled with.
    last: Option<crate::strat::AllocStats>,
}

impl EngineBackend<UniformEngine> {
    /// Uniform m-Cubes backend (the historical `NativeBackend`).
    pub fn uniform(
        integrand: IntegrandRef,
        layout: Layout,
        threads: usize,
    ) -> EngineBackend<UniformEngine> {
        EngineBackend::new(integrand, UniformEngine::new(layout), threads)
    }
}

impl EngineBackend<VegasPlusEngine> {
    /// VEGAS+ adaptively-stratified backend (the historical
    /// `StratifiedBackend`), resuming `resume`'s allocation when its
    /// cube count matches `layout`.
    pub fn vegas_plus(
        integrand: IntegrandRef,
        layout: Layout,
        threads: usize,
        beta: f64,
        resume: Option<&StratSnapshot>,
    ) -> Result<EngineBackend<VegasPlusEngine>> {
        Ok(EngineBackend::new(
            integrand,
            VegasPlusEngine::new(layout, beta, resume)?,
            threads,
        ))
    }
}

impl<E: Engine> EngineBackend<E> {
    /// Wrap an engine the caller built — the seam custom engines (and
    /// `Box<dyn Engine>`) plug into.
    pub fn new(integrand: IntegrandRef, engine: E, threads: usize) -> EngineBackend<E> {
        EngineBackend {
            integrand,
            threads,
            exec: ExecPath::default(),
            engine,
            last: None,
        }
    }

    /// Chainable override of the execution schedule (default:
    /// streaming). Both paths are bitwise identical — this is a
    /// performance knob, surfaced through `JobConfig::exec`.
    #[must_use]
    pub fn with_exec(mut self, exec: ExecPath) -> Self {
        self.exec = exec;
        self
    }

    /// The wrapped engine (test/inspection hook).
    pub fn engine(&self) -> &E {
        &self.engine
    }
}

impl<E: Engine> VSampleBackend for EngineBackend<E> {
    fn layout(&self) -> Layout {
        *self.engine.layout()
    }

    fn bounds(&self) -> Bounds {
        self.integrand.bounds()
    }

    fn name(&self) -> &'static str {
        self.engine.name()
    }

    fn run(
        &mut self,
        bins: &Bins,
        seed: u32,
        iteration: u32,
        adjust: bool,
    ) -> Result<(IterationResult, Option<Vec<f64>>)> {
        // Snapshot before the pass: observers see the allocation this
        // iteration actually sampled with, not the re-apportioned one
        // the engine's update leaves behind for the next iteration.
        self.last = self.engine.alloc_stats();
        let opts = VSampleOpts {
            seed,
            iteration,
            adjust,
            threads: self.threads,
        };
        Ok(self
            .engine
            .vsample(&*self.integrand, bins, &opts, FillPath::Simd, self.exec))
    }

    fn alloc_stats(&self) -> Option<crate::strat::AllocStats> {
        self.last
    }

    fn strat_export(&self) -> Option<StratSnapshot> {
        self.engine.export()
    }
}

/// PJRT-artifact backend: holds the adjust and no-adjust executables
/// for one (integrand, calls) pair (the paper's V-Sample /
/// V-Sample-No-Adjust kernel pair).
pub struct PjrtBackend {
    adj: Arc<VSampleExecutable>,
    na: Option<Arc<VSampleExecutable>>,
}

impl PjrtBackend {
    /// Load from a registry: picks the smallest artifact pair with
    /// `maxcalls >= min_calls` for `integrand`.
    pub fn load(
        runtime: &PjrtRuntime,
        registry: &Registry,
        integrand: &str,
        min_calls: usize,
    ) -> Result<PjrtBackend> {
        let adj_meta = registry.select(integrand, true, min_calls)?;
        let adj = runtime.load(registry, adj_meta)?;
        // The no-adjust twin is optional; fall back to the adjust
        // executable (correct, just slower) when absent.
        let na = registry
            .select(integrand, false, adj_meta.maxcalls)
            .ok()
            .filter(|m| m.maxcalls == adj_meta.maxcalls)
            .map(|m| runtime.load(registry, m))
            .transpose()?;
        Ok(PjrtBackend { adj, na })
    }

    pub fn from_executables(
        adj: Arc<VSampleExecutable>,
        na: Option<Arc<VSampleExecutable>>,
    ) -> PjrtBackend {
        PjrtBackend { adj, na }
    }

    pub fn meta(&self) -> &ArtifactMeta {
        self.adj.meta()
    }
}

impl VSampleBackend for PjrtBackend {
    fn layout(&self) -> Layout {
        self.adj.meta().layout()
    }

    fn bounds(&self) -> Bounds {
        let meta = self.adj.meta();
        Bounds::uniform(meta.dim, meta.lo, meta.hi)
    }

    fn name(&self) -> &'static str {
        "pjrt"
    }

    fn run(
        &mut self,
        bins: &Bins,
        seed: u32,
        iteration: u32,
        adjust: bool,
    ) -> Result<(IterationResult, Option<Vec<f64>>)> {
        if adjust {
            self.adj.vsample(bins, seed, iteration)
        } else if let Some(na) = &self.na {
            na.vsample(bins, seed, iteration)
        } else {
            // Fall back: run the adjust kernel, drop the histogram.
            let (r, _) = self.adj.vsample(bins, seed, iteration)?;
            Ok((r, None))
        }
    }
}
