//! Execution backends for one V-Sample pass.
//!
//! The driver is backend-agnostic: `PjrtBackend` runs the AOT Pallas
//! artifact through PJRT (the paper's GPU kernel), `NativeBackend` runs
//! the Rust engine (the paper's Kokkos-style second platform). Both
//! draw identical Philox streams, so for the same (seed, iteration) the
//! results agree to summation-order tolerance.
//!
//! Both backends are batch-first: the artifact evaluates whole
//! per-thread-block sample batches on device, and the native engine
//! mirrors that with its fill-block → `Integrand::eval_batch` → reduce
//! pipeline over [`crate::engine::PointBlock`]s — one virtual call per
//! block, never one per point.

use crate::api::StratSnapshot;
use crate::engine::{vsample_stratified_exec, ExecPath, FillPath, NativeEngine, VSampleOpts};
use crate::error::Result;
use crate::estimator::IterationResult;
use crate::grid::Bins;
use crate::integrands::{Integrand, IntegrandRef};
use crate::runtime::{ArtifactMeta, PjrtRuntime, Registry, VSampleExecutable};
use crate::strat::{Allocation, Bounds, Layout};
use std::cell::RefCell;
use std::sync::Arc;

/// One V-Sample pass provider.
pub trait VSampleBackend {
    /// Stratification layout (fixed per backend instance).
    fn layout(&self) -> Layout;
    /// Per-axis integration-box bounds.
    fn bounds(&self) -> Bounds;
    /// Backend label for reports ("pjrt" / "native").
    fn name(&self) -> &'static str;
    /// Run one iteration; histogram returned only when `adjust`.
    fn run(
        &self,
        bins: &Bins,
        seed: u32,
        iteration: u32,
        adjust: bool,
    ) -> Result<(IterationResult, Option<Vec<f64>>)>;
    /// Per-cube allocation summary of the *most recent* `run` call —
    /// `Some` only for adaptively-stratified backends (VEGAS+). The
    /// driver forwards it to observers via `IterationEvent::alloc`.
    fn alloc_stats(&self) -> Option<crate::strat::AllocStats> {
        None
    }
    /// Export the live per-cube allocation state, when this backend is
    /// adaptively stratified — the session layer stores it in
    /// `GridState`/`Checkpoint` so warm starts and suspended runs
    /// resume the allocation bit-identically.
    fn strat_export(&self) -> Option<StratSnapshot> {
        None
    }
    /// Cumulative shard-execution accounting — `Some` only for the
    /// sharded backend ([`crate::shard::ShardedBackend`]). The session
    /// layer folds it across stages; the service layer surfaces it in
    /// `ServiceMetrics`.
    fn shard_stats(&self) -> Option<crate::shard::ShardStats> {
        None
    }
}

/// Native-engine backend.
pub struct NativeBackend {
    integrand: Arc<dyn Integrand>,
    layout: Layout,
    threads: usize,
    exec: ExecPath,
}

impl NativeBackend {
    pub fn new(integrand: Arc<dyn Integrand>, layout: Layout, threads: usize) -> Self {
        NativeBackend {
            integrand,
            layout,
            threads,
            exec: ExecPath::default(),
        }
    }

    /// Chainable override of the execution schedule (default:
    /// streaming). Both paths are bitwise identical — this is a
    /// performance knob, surfaced through `JobConfig::exec`.
    #[must_use]
    pub fn with_exec(mut self, exec: ExecPath) -> Self {
        self.exec = exec;
        self
    }
}

impl VSampleBackend for NativeBackend {
    fn layout(&self) -> Layout {
        self.layout
    }

    fn bounds(&self) -> Bounds {
        self.integrand.bounds()
    }

    fn name(&self) -> &'static str {
        "native"
    }

    fn run(
        &self,
        bins: &Bins,
        seed: u32,
        iteration: u32,
        adjust: bool,
    ) -> Result<(IterationResult, Option<Vec<f64>>)> {
        let opts = VSampleOpts {
            seed,
            iteration,
            adjust,
            threads: self.threads,
        };
        Ok(NativeEngine.vsample_exec(
            &*self.integrand,
            &self.layout,
            bins,
            &opts,
            FillPath::Simd,
            self.exec,
        ))
    }
}

/// Mutable per-run state of the stratified backend: the live
/// allocation plus the stats snapshot of the iteration that just ran.
struct StratCell {
    alloc: Allocation,
    last: Option<crate::strat::AllocStats>,
}

/// VEGAS+ adaptively-stratified twin of [`NativeBackend`]: drives
/// the stratified V-Sample pass (fused streaming schedule by default,
/// selectable via [`StratifiedBackend::with_exec`]) with a live
/// [`Allocation`], re-apportioning the per-iteration budget after
/// every pass. The driver stays allocation-agnostic — it only sees the
/// [`VSampleBackend`] contract plus `alloc_stats`/`strat_export`.
pub struct StratifiedBackend {
    integrand: IntegrandRef,
    layout: Layout,
    threads: usize,
    beta: f64,
    exec: ExecPath,
    /// Per-iteration call budget (`layout.calls()`, matching the
    /// uniform engine so `calls_used` accounting is identical).
    budget: usize,
    state: RefCell<StratCell>,
}

impl StratifiedBackend {
    /// Build a stratified backend, resuming `resume`'s allocation when
    /// its cube count matches `layout` (the re-apportionment is a pure
    /// function of the damped accumulator, so a matching snapshot
    /// restores the exact per-cube counts); any mismatch starts from
    /// the uniform split.
    pub fn new(
        integrand: IntegrandRef,
        layout: Layout,
        threads: usize,
        beta: f64,
        resume: Option<&StratSnapshot>,
    ) -> Result<StratifiedBackend> {
        let alloc = match resume {
            Some(s) if s.counts.len() == layout.m => {
                let mut a = Allocation::from_parts(s.counts.clone(), s.damped.clone())?;
                a.reallocate(layout.calls(), beta);
                a
            }
            _ => Allocation::uniform(&layout),
        };
        Ok(StratifiedBackend {
            integrand,
            layout,
            threads,
            beta,
            exec: ExecPath::default(),
            budget: layout.calls(),
            state: RefCell::new(StratCell { alloc, last: None }),
        })
    }

    /// Chainable override of the execution schedule (default:
    /// streaming) — same contract as [`NativeBackend::with_exec`].
    #[must_use]
    pub fn with_exec(mut self, exec: ExecPath) -> Self {
        self.exec = exec;
        self
    }
}

impl VSampleBackend for StratifiedBackend {
    fn layout(&self) -> Layout {
        self.layout
    }

    fn bounds(&self) -> Bounds {
        self.integrand.bounds()
    }

    fn name(&self) -> &'static str {
        "native-vegas+"
    }

    fn run(
        &self,
        bins: &Bins,
        seed: u32,
        iteration: u32,
        adjust: bool,
    ) -> Result<(IterationResult, Option<Vec<f64>>)> {
        let mut cell = self.state.borrow_mut();
        let StratCell { alloc, last } = &mut *cell;
        *last = Some(alloc.stats());
        let opts = VSampleOpts {
            seed,
            iteration,
            adjust,
            threads: self.threads,
        };
        let out = vsample_stratified_exec(
            &*self.integrand,
            &self.layout,
            bins,
            alloc,
            &opts,
            FillPath::Simd,
            self.exec,
        );
        // Re-apportion for the next iteration from the freshly damped
        // accumulator (cheap; also leaves the exported snapshot ready
        // for warm starts even when this was the final iteration).
        alloc.reallocate(self.budget, self.beta);
        Ok(out)
    }

    fn alloc_stats(&self) -> Option<crate::strat::AllocStats> {
        self.state.borrow().last
    }

    fn strat_export(&self) -> Option<StratSnapshot> {
        let cell = self.state.borrow();
        Some(StratSnapshot {
            beta: self.beta,
            counts: cell.alloc.counts().to_vec(),
            damped: cell.alloc.damped().to_vec(),
        })
    }
}

/// PJRT-artifact backend: holds the adjust and no-adjust executables
/// for one (integrand, calls) pair (the paper's V-Sample /
/// V-Sample-No-Adjust kernel pair).
pub struct PjrtBackend {
    adj: Arc<VSampleExecutable>,
    na: Option<Arc<VSampleExecutable>>,
}

impl PjrtBackend {
    /// Load from a registry: picks the smallest artifact pair with
    /// `maxcalls >= min_calls` for `integrand`.
    pub fn load(
        runtime: &PjrtRuntime,
        registry: &Registry,
        integrand: &str,
        min_calls: usize,
    ) -> Result<PjrtBackend> {
        let adj_meta = registry.select(integrand, true, min_calls)?;
        let adj = runtime.load(registry, adj_meta)?;
        // The no-adjust twin is optional; fall back to the adjust
        // executable (correct, just slower) when absent.
        let na = registry
            .select(integrand, false, adj_meta.maxcalls)
            .ok()
            .filter(|m| m.maxcalls == adj_meta.maxcalls)
            .map(|m| runtime.load(registry, m))
            .transpose()?;
        Ok(PjrtBackend { adj, na })
    }

    pub fn from_executables(
        adj: Arc<VSampleExecutable>,
        na: Option<Arc<VSampleExecutable>>,
    ) -> PjrtBackend {
        PjrtBackend { adj, na }
    }

    pub fn meta(&self) -> &ArtifactMeta {
        self.adj.meta()
    }
}

impl VSampleBackend for PjrtBackend {
    fn layout(&self) -> Layout {
        self.adj.meta().layout()
    }

    fn bounds(&self) -> Bounds {
        let meta = self.adj.meta();
        Bounds::uniform(meta.dim, meta.lo, meta.hi)
    }

    fn name(&self) -> &'static str {
        "pjrt"
    }

    fn run(
        &self,
        bins: &Bins,
        seed: u32,
        iteration: u32,
        adjust: bool,
    ) -> Result<(IterationResult, Option<Vec<f64>>)> {
        if adjust {
            self.adj.vsample(bins, seed, iteration)
        } else if let Some(na) = &self.na {
            na.vsample(bins, seed, iteration)
        } else {
            // Fall back: run the adjust kernel, drop the histogram.
            let (r, _) = self.adj.vsample(bins, seed, iteration)?;
            Ok((r, None))
        }
    }
}
