//! Table 2 reproduction: execution-platform portability overhead.
//!
//! Paper: the same algorithm on CUDA vs the Kokkos port, reporting
//! kernel time vs total time on fA and fB (10-50% overhead).
//! Substitution (DESIGN.md): our two backends are the AOT PJRT artifact
//! (primary) and the native Rust engine (portable second platform);
//! we report kernel vs total time for each on the same workloads.
//! CSV: results/table2_portability.csv

use mcubes::api::{Integrator, RunPlan};
use mcubes::coordinator::{drive, JobConfig, PjrtBackend};
use mcubes::runtime::{PjrtRuntime, Registry};
use mcubes::util::table::Table;
use std::path::Path;

fn artifacts_dir() -> Option<&'static str> {
    ["artifacts", "../artifacts"]
        .into_iter()
        .find(|d| Path::new(d).join("manifest.json").exists())
}

fn main() {
    println!("== Table 2: backend portability (kernel vs total time, ms) ==\n");
    let Some(dir) = artifacts_dir() else {
        println!("SKIP: artifacts/ missing — run `make artifacts`");
        return;
    };
    let reg = Registry::load(dir).expect("manifest");
    let runtime = PjrtRuntime::cpu().expect("pjrt");

    let mut table = Table::new(&["integrand", "platform", "kernel", "total", "kernel %"]);
    let mut csv = Table::new(&["integrand", "platform", "kernel_ms", "total_ms"]);

    for name in ["fA", "fB"] {
        let mut backend = PjrtBackend::load(&runtime, &reg, name, 0).expect("artifact");
        let meta = backend.meta().clone();
        let cfg = JobConfig::default()
            .with_maxcalls(meta.maxcalls)
            .with_bins(meta.nb)
            .with_blocks(meta.nblocks)
            .with_plan(RunPlan::classic(10, 7, 1))
            .with_tolerance(1e-13) // fixed work: run all iterations
            .with_seed(77);
        let mut native = Integrator::from_registry(&meta.integrand, meta.dim)
            .expect("integrand")
            .config(cfg.clone());
        // Warm both paths (compile cache, page faults).
        let _ = drive(&mut backend, &cfg, None, None).unwrap();
        let pjrt_out = drive(&mut backend, &cfg, None, None).unwrap().output;
        let _ = native.run().unwrap();
        let native_out = native.run().unwrap();

        for (platform, out) in [("pjrt-aot", &pjrt_out), ("native-rust", &native_out)] {
            table.row(vec![
                name.into(),
                platform.into(),
                format!("{:.3}", out.kernel_time * 1e3),
                format!("{:.3}", out.total_time * 1e3),
                format!("{:.1}%", 100.0 * out.kernel_time / out.total_time),
            ]);
            csv.row(vec![
                name.into(),
                platform.into(),
                format!("{:.3}", out.kernel_time * 1e3),
                format!("{:.3}", out.total_time * 1e3),
            ]);
        }
        let overhead =
            (pjrt_out.kernel_time / native_out.kernel_time.max(1e-12) - 1.0) * 100.0;
        println!("{name}: pjrt kernel overhead vs native: {overhead:+.1}%");
    }
    println!("\n{}", table.render());
    println!("(paper shape: second platform within ~10-50% on kernel time)");
    let _ = csv.write_csv("results/table2_portability.csv");
    println!("series written to results/table2_portability.csv");
}
