//! Table 1 reproduction: m-Cubes vs ZMCintegral on fA (6-D oscillatory
//! over (0,10)^6) and fB (9-D narrow Gaussian over (-1,1)^9) — the
//! paper reports estimate, error estimate, and time, with m-Cubes 45x
//! and 10x faster at markedly smaller error estimates.
//! CSV: results/table1_zmc.csv

use mcubes::api::{Integrator, RunPlan};
use mcubes::baselines::{zmc_integrate, ZmcConfig};
use mcubes::integrands::by_name;
use mcubes::util::table::Table;

fn main() {
    println!("== Table 1: comparison with ZMCintegral (fA, fB) ==\n");
    let mut table = Table::new(&[
        "integrand", "alg", "true value", "estimate", "errorest", "time (ms)",
    ]);
    let mut csv = Table::new(&["integrand", "alg", "estimate", "errorest", "time_ms"]);

    // (name, dim, zmc config, mcubes calls, mcubes itmax)
    // ZMC params follow the paper §5.2: same integrands, depth-limited
    // tree search; m-Cubes uses tau 1e-3 with itmax 10 / 15.
    let cases: [(&str, usize, ZmcConfig, usize, usize); 2] = [
        (
            "fA",
            6,
            ZmcConfig {
                k: 3,
                samples_per_block: 1024,
                depth: 3,
                select_frac: 0.3,
                seed: 11,
                max_blocks: 1 << 17,
            },
            1 << 22,
            10,
        ),
        (
            "fB",
            9,
            ZmcConfig {
                k: 2,
                samples_per_block: 192,
                depth: 3,
                select_frac: 0.3,
                seed: 11,
                max_blocks: 1 << 16,
            },
            1 << 19,
            15,
        ),
    ];

    for (name, d, zcfg, calls, itmax) in cases {
        let f = by_name(name, d).expect("integrand");
        let truth = f.true_value().unwrap();

        let z = zmc_integrate(&*f, &zcfg);
        let m = Integrator::new(f.clone())
            .maxcalls(calls)
            .tolerance(1e-3)
            .plan(RunPlan::classic(itmax, itmax, 2))
            .seed(11)
            .run()
            .expect("mcubes");

        for (alg, est, err, secs) in [
            ("zmc-sim", z.integral, z.sigma, z.total_time),
            ("m-Cubes", m.integral, m.sigma, m.total_time),
        ] {
            table.row(vec![
                name.into(),
                alg.into(),
                format!("{truth:.6}"),
                format!("{est:.5}"),
                format!("{err:.5}"),
                format!("{:.2e}", secs * 1e3),
            ]);
            csv.row(vec![
                name.into(),
                alg.into(),
                format!("{est:e}"),
                format!("{err:e}"),
                format!("{:.3}", secs * 1e3),
            ]);
        }
        let speedup = z.total_time / m.total_time.max(1e-12);
        println!(
            "{name}: m-Cubes speedup {speedup:.1}x, errorest ratio {:.1}x smaller",
            z.sigma / m.sigma.max(1e-300)
        );
    }
    println!("\n{}", table.render());
    println!("(paper shape: m-Cubes ~45x/10x faster with much smaller errorest)");
    let _ = csv.write_csv("results/table1_zmc.csv");
    println!("series written to results/table1_zmc.csv");
}
