//! Hot-path microbenchmarks (§5.3 analogue + the §Perf iteration log):
//!   * Philox uniform generation throughput
//!   * native V-Sample throughput (evals/s) per integrand
//!   * integrand-evaluation share of total time (paper §5.3: <1%-18%)
//!   * bin-adjustment (smooth+rebin) cost
//!   * batched vs scalar-default evaluation (the PointBlock redesign)
//!   * uniform m-Cubes vs VEGAS+ adaptive stratification (calls to tau)
//!   * shard scaling (one iteration over N in-process shard workers)
//!   * Engine dispatch overhead (static vs `Box<dyn Engine>` vtable)
//! CSV: results/perf_microbench.csv; `BENCH {...}` JSON lines record
//! the batch-vs-scalar and sampling-strategy series for the perf
//! trajectory.

// Narrowing / float→int casts in this file are deliberate and
// audited by `cargo xtask lint` (MC001); see docs/invariants.md.
#![allow(clippy::cast_possible_truncation)]

use mcubes::api::{Integrator, RunPlan, Sampling};
use mcubes::coordinator::{IntegrationOutput, JobConfig, JobRequest, Scheduler, VSampleBackend};
use mcubes::engine::{
    Engine, ExecPath, FillPath, NativeEngine, PointBlock, ScalarEval, UniformEngine, VSampleOpts,
    VegasMap, BLOCK_POINTS,
};
use mcubes::grid::Bins;
use mcubes::integrands::by_name;
use mcubes::rng::philox_simd::LANES;
use mcubes::rng::uniforms_into;
use mcubes::shard::ShardedBackend;
use mcubes::strat::Layout;
use mcubes::util::benchkit::{bench, black_box, emit_bench, BenchOpts};
use mcubes::util::table::Table;

fn main() {
    let opts = BenchOpts {
        warmup: 1,
        runs: 5,
        ..Default::default()
    }
    .quick_aware();
    let mut csv = Table::new(&["bench", "metric", "value"]);

    // ---- Philox throughput -------------------------------------------
    {
        let n = 1_000_000u32;
        let stats = bench(opts, || {
            let mut buf = [0.0f64; 8];
            let mut acc = 0.0;
            for s in 0..n {
                uniforms_into(s as u64, 0, 42, &mut buf);
                acc += buf[0];
            }
            black_box(acc)
        });
        let per_sec = (n as f64 * 8.0) / (stats.median_ms() / 1e3);
        println!(
            "philox: {:.1}M uniforms/s  (1M samples x 8 dims in {:.1} ms)",
            per_sec / 1e6,
            stats.median_ms()
        );
        csv.row(vec![
            "philox".into(),
            "uniforms_per_sec".into(),
            format!("{per_sec:.0}"),
        ]);
    }

    // ---- Engine V-Sample throughput per integrand ---------------------
    println!("\nnative V-Sample throughput (adjust variant):");
    let mut table = Table::new(&["integrand", "d", "calls", "ms/iter", "Mevals/s", "eval share"]);
    for (name, d) in [
        ("f1", 5),
        ("f2", 6),
        ("f3", 8),
        ("f4", 5),
        ("f5", 8),
        ("f6", 6),
        ("fA", 6),
        ("fB", 9),
        ("cosmo", 6),
    ] {
        let f = by_name(name, d).unwrap();
        let calls = 1 << 17;
        let layout = Layout::compute(d, calls, 50, 8).unwrap();
        let bins = Bins::uniform(d, 50);
        let vopts = VSampleOpts {
            seed: 1,
            iteration: 0,
            adjust: true,
            threads: 1,
        };
        let stats = bench(opts, || {
            black_box(NativeEngine.vsample(&*f, &layout, &bins, &vopts))
        });
        // Integrand-evaluation share (paper §5.3): time the bare evals.
        let mut xs = vec![0.5f64; d];
        let n_eval = layout.calls();
        let eval_stats = bench(opts, || {
            let mut acc = 0.0;
            for i in 0..n_eval {
                xs[0] = (i & 1023) as f64 / 1024.0;
                acc += f.eval(&xs);
            }
            black_box(acc)
        });
        let share = eval_stats.median_ms() / stats.median_ms() * 100.0;
        let mevals = layout.calls() as f64 / (stats.median_ms() / 1e3) / 1e6;
        table.row(vec![
            name.into(),
            d.to_string(),
            layout.calls().to_string(),
            format!("{:.2}", stats.median_ms()),
            format!("{mevals:.2}"),
            format!("{share:.0}%"),
        ]);
        csv.row(vec![
            format!("vsample_{name}"),
            "mevals_per_sec".into(),
            format!("{mevals:.3}"),
        ]);
        csv.row(vec![
            format!("evalshare_{name}"),
            "percent".into(),
            format!("{share:.1}"),
        ]);
    }
    println!("{}", table.render());

    // ---- Bin adjustment cost ------------------------------------------
    {
        let d = 8;
        let nb = 500; // paper-scale bin count
        let mut bins = Bins::uniform(d, nb);
        let contrib: Vec<f64> = (0..d * nb).map(|i| ((i % 97) as f64).sin().abs()).collect();
        let stats = bench(opts, || {
            bins.adjust(black_box(&contrib));
        });
        println!(
            "bin adjust (d={d}, nb={nb}): {:.3} ms/iteration",
            stats.median_ms()
        );
        csv.row(vec![
            "bin_adjust_d8_nb500".into(),
            "ms".into(),
            format!("{:.4}", stats.median_ms()),
        ]);
    }

    // ---- Batched vs scalar-default evaluation -------------------------
    // Same engine pipeline twice: once with the integrand's hand-batched
    // eval_batch, once through ScalarEval (the default gather-and-call
    // loop). Results are bitwise identical (property-tested); only the
    // evaluation organization differs — exactly the redesign's payoff.
    {
        println!("\nbatched vs scalar-default evaluation (V-Sample, 1 thread):");
        let mut table = Table::new(&[
            "integrand", "d", "batch ms", "scalar ms", "speedup", "batch Mevals/s",
        ]);
        for (name, d) in [("f4", 5), ("f4", 8), ("f5", 5), ("f5", 8)] {
            let f = by_name(name, d).unwrap();
            let calls = 1 << 17;
            let layout = Layout::compute(d, calls, 50, 8).unwrap();
            let bins = Bins::uniform(d, 50);
            let vopts = VSampleOpts {
                seed: 1,
                iteration: 0,
                adjust: true,
                threads: 1,
            };
            let t_batch = bench(opts, || {
                black_box(NativeEngine.vsample(&*f, &layout, &bins, &vopts))
            });
            let scalar = ScalarEval(&*f);
            let t_scalar = bench(opts, || {
                black_box(NativeEngine.vsample(&scalar, &layout, &bins, &vopts))
            });
            let speedup = t_scalar.median_ms() / t_batch.median_ms();
            let mevals = layout.calls() as f64 / (t_batch.median_ms() / 1e3) / 1e6;
            table.row(vec![
                name.into(),
                d.to_string(),
                format!("{:.2}", t_batch.median_ms()),
                format!("{:.2}", t_scalar.median_ms()),
                format!("{speedup:.2}x"),
                format!("{mevals:.2}"),
            ]);
            let tag = format!("batch_vs_scalar_{name}_d{d}");
            emit_bench(&tag, "batch_ms", t_batch.median_ms(), "ms");
            emit_bench(&tag, "scalar_ms", t_scalar.median_ms(), "ms");
            emit_bench(&tag, "speedup", speedup, "x");
            csv.row(vec![
                tag.clone(),
                "speedup".into(),
                format!("{speedup:.4}"),
            ]);
            csv.row(vec![
                tag,
                "batch_mevals_per_sec".into(),
                format!("{mevals:.3}"),
            ]);
        }
        println!("{}", table.render());
    }

    // ---- SIMD vs scalar fill (the lane-parallel sampling core) --------
    // Two measurements per case. (1) The fill phase in isolation —
    // Philox + VEGAS transform into a PointBlock, no evaluation and no
    // reduction — comparing `VegasMap::fill_points` (lane-parallel)
    // against `fill_points_scalar` (the per-point reference). This is
    // the `simd_fill_speedup` series. (2) The whole V-Sample pass under
    // each FillPath, which dilutes the win by the eval + reduce share.
    // Both paths are bitwise identical (property-tested); only the
    // schedule differs.
    {
        println!("\nSIMD vs scalar fill ({LANES} lanes, 1 thread):");
        let mut table = Table::new(&[
            "integrand", "d", "simd fill ms", "scalar fill ms", "fill speedup",
            "vsample speedup",
        ]);
        for (name, d) in [("f4", 5), ("f4", 8), ("f5", 5), ("f5", 8)] {
            let f = by_name(name, d).unwrap();
            let calls = 1 << 17;
            let layout = Layout::compute(d, calls, 50, 8).unwrap();
            let bins = Bins::uniform(d, 50);
            let map = VegasMap::new(&layout, &bins, &f.bounds());
            let p = layout.p;
            // Mirror the engine's block loop exactly: whole-cube
            // batches with lane groups running across cube boundaries.
            let cubes_per_block = (BLOCK_POINTS / p).max(1);
            let cap = cubes_per_block * p;
            let mut blk = PointBlock::with_capacity(d, cap);
            let mut bidx = vec![0usize; cap * d];
            let mut cube_coords = vec![0usize; cubes_per_block * d];
            let mut coords = vec![0usize; d];
            let mut bench_fill = |path: FillPath| {
                bench(opts, || {
                    let mut acc = 0.0;
                    let mut cube = 0usize;
                    while cube < layout.m {
                        let ncubes = cubes_per_block.min(layout.m - cube);
                        blk.reset(ncubes * p);
                        for c in 0..ncubes {
                            layout.cube_coords(cube + c, &mut coords);
                            cube_coords[c * d..(c + 1) * d].copy_from_slice(&coords);
                        }
                        let base = cube as u64 * p as u64;
                        match path {
                            FillPath::Simd => map.fill_span(
                                &cube_coords[..ncubes * d],
                                ncubes,
                                p,
                                base,
                                0,
                                1,
                                &mut blk,
                                &mut bidx,
                            ),
                            FillPath::Scalar => {
                                for c in 0..ncubes {
                                    map.fill_points_scalar(
                                        &cube_coords[c * d..(c + 1) * d],
                                        base + (c * p) as u64,
                                        p,
                                        0,
                                        1,
                                        &mut blk,
                                        c * p,
                                        &mut bidx,
                                    );
                                }
                            }
                        }
                        acc += blk.jac(0);
                        cube += ncubes;
                    }
                    black_box(acc)
                })
            };
            let t_fill_simd = bench_fill(FillPath::Simd);
            let t_fill_scalar = bench_fill(FillPath::Scalar);
            let fill_speedup = t_fill_scalar.median_ms() / t_fill_simd.median_ms();

            let vopts = VSampleOpts {
                seed: 1,
                iteration: 0,
                adjust: true,
                threads: 1,
            };
            let t_vs_simd = bench(opts, || {
                black_box(NativeEngine.vsample_exec(
                    &*f,
                    &layout,
                    &bins,
                    &vopts,
                    FillPath::Simd,
                    ExecPath::default(),
                ))
            });
            let t_vs_scalar = bench(opts, || {
                black_box(NativeEngine.vsample_exec(
                    &*f,
                    &layout,
                    &bins,
                    &vopts,
                    FillPath::Scalar,
                    ExecPath::default(),
                ))
            });
            let vsample_speedup = t_vs_scalar.median_ms() / t_vs_simd.median_ms();

            table.row(vec![
                name.into(),
                d.to_string(),
                format!("{:.2}", t_fill_simd.median_ms()),
                format!("{:.2}", t_fill_scalar.median_ms()),
                format!("{fill_speedup:.2}x"),
                format!("{vsample_speedup:.2}x"),
            ]);
            let tag = format!("simd_fill_{name}_d{d}");
            emit_bench(&tag, "simd_fill_ms", t_fill_simd.median_ms(), "ms");
            emit_bench(&tag, "scalar_fill_ms", t_fill_scalar.median_ms(), "ms");
            emit_bench(&tag, "simd_fill_speedup", fill_speedup, "x");
            emit_bench(&tag, "simd_vsample_speedup", vsample_speedup, "x");
            emit_bench(&tag, "lanes", LANES as f64, "lanes");
            csv.row(vec![
                tag.clone(),
                "simd_fill_speedup".into(),
                format!("{fill_speedup:.4}"),
            ]);
            csv.row(vec![
                tag,
                "simd_vsample_speedup".into(),
                format!("{vsample_speedup:.4}"),
            ]);
        }
        println!("{}", table.render());
    }

    // ---- Streaming vs block execution schedule ------------------------
    // The fused streaming tile loop (engine::walk, the default
    // ExecPath) against the historical whole-block pipeline, on the
    // cheap integrands where the block path is memory-bandwidth-bound.
    // Results are bitwise identical (property-tested); this series is
    // the tentpole's throughput evidence and the regression gate's
    // primary input (tools/ci/check_bench_regression.py).
    {
        println!("\nstreaming vs block execution (fused tile loop, f1/f2/f4 d=8):");
        let mut table = Table::new(&[
            "integrand", "d", "threads", "block ms", "stream ms", "speedup", "Mevals/s",
        ]);
        for (name, d) in [("f1", 8), ("f2", 8), ("f4", 8)] {
            let f = by_name(name, d).unwrap();
            let calls = 1 << 17;
            let layout = Layout::compute(d, calls, 50, 8).unwrap();
            let bins = Bins::uniform(d, 50);
            for threads in [1usize, 8] {
                let vopts = VSampleOpts {
                    seed: 1,
                    iteration: 0,
                    adjust: true,
                    threads,
                };
                let t_block = bench(opts, || {
                    black_box(NativeEngine.vsample_exec(
                        &*f,
                        &layout,
                        &bins,
                        &vopts,
                        FillPath::Simd,
                        ExecPath::Block,
                    ))
                });
                let t_stream = bench(opts, || {
                    black_box(NativeEngine.vsample_exec(
                        &*f,
                        &layout,
                        &bins,
                        &vopts,
                        FillPath::Simd,
                        ExecPath::Streaming,
                    ))
                });
                let speedup = t_block.median_ms() / t_stream.median_ms();
                let mevals = layout.calls() as f64 / (t_stream.median_ms() / 1e3) / 1e6;
                table.row(vec![
                    name.into(),
                    d.to_string(),
                    threads.to_string(),
                    format!("{:.2}", t_block.median_ms()),
                    format!("{:.2}", t_stream.median_ms()),
                    format!("{speedup:.2}x"),
                    format!("{mevals:.2}"),
                ]);
                let tag = format!("streaming_{name}_d{d}_t{threads}");
                emit_bench(&tag, "block_ms", t_block.median_ms(), "ms");
                emit_bench(&tag, "streaming_ms", t_stream.median_ms(), "ms");
                emit_bench(&tag, "streaming_speedup", speedup, "x");
                emit_bench(&tag, "streaming_mevals_per_sec", mevals * 1e6, "evals/s");
                csv.row(vec![
                    tag.clone(),
                    "streaming_speedup".into(),
                    format!("{speedup:.4}"),
                ]);
                csv.row(vec![
                    tag,
                    "streaming_mevals_per_sec".into(),
                    format!("{mevals:.3}"),
                ]);
            }
        }
        println!("{}", table.render());
    }

    // ---- Adjust vs no-adjust engine delta (two-phase payoff) ----------
    {
        let f = by_name("f5", 8).unwrap();
        let layout = Layout::compute(8, 1 << 17, 50, 8).unwrap();
        let bins = Bins::uniform(8, 50);
        let t_adj = bench(opts, || {
            black_box(NativeEngine.vsample(
                &*f,
                &layout,
                &bins,
                &VSampleOpts {
                    seed: 1,
                    iteration: 0,
                    adjust: true,
                    threads: 1,
                },
            ))
        });
        let t_na = bench(opts, || {
            black_box(NativeEngine.vsample(
                &*f,
                &layout,
                &bins,
                &VSampleOpts {
                    seed: 1,
                    iteration: 0,
                    adjust: false,
                    threads: 1,
                },
            ))
        });
        println!(
            "V-Sample vs No-Adjust (f5 d=8): {:.2} ms vs {:.2} ms ({:.1}% saved)",
            t_adj.median_ms(),
            t_na.median_ms(),
            (1.0 - t_na.median_ms() / t_adj.median_ms()) * 100.0
        );
        csv.row(vec![
            "na_saving_f5d8".into(),
            "percent".into(),
            format!(
                "{:.2}",
                (1.0 - t_na.median_ms() / t_adj.median_ms()) * 100.0
            ),
        ]);
    }

    // ---- Uniform vs VEGAS+ adaptive stratification --------------------
    // Same per-iteration budget, seed, and tolerance; both strategies
    // drive until tau is met. VEGAS+ re-apportions each iteration's
    // samples toward high-variance sub-cubes, so on peaked integrands
    // (f4, cosmo) it should reach tau with fewer total calls; f5 is the
    // smooth control where the two should be comparable.
    {
        println!("\nuniform vs VEGAS+ sampling (total calls to reach tau):");
        let mut table = Table::new(&[
            "integrand",
            "d",
            "tau",
            "uniform calls",
            "vegas+ calls",
            "ratio",
            "uniform rel",
            "vegas+ rel",
        ]);
        for (name, d, calls, tau) in [
            ("f4", 8, 1usize << 16, 5e-3),
            ("f5", 8, 1usize << 15, 1e-3),
            ("cosmo", 6, 1usize << 16, 5e-3),
        ] {
            let run = |sampling: Sampling| {
                Integrator::from_registry(name, d)
                    .expect("registry integrand")
                    .maxcalls(calls)
                    .tolerance(tau)
                    .plan(RunPlan::classic(60, 48, 2))
                    .seed(2024)
                    .sampling(sampling)
                    .run()
                    .expect("integration run")
            };
            let uni = run(Sampling::Uniform);
            let vp = run(Sampling::vegas_plus());
            let truth = by_name(name, d).unwrap().true_value();
            let rel = |out: &IntegrationOutput| match truth {
                Some(t) => ((out.integral - t) / t).abs(),
                None => out.rel_err,
            };
            let ratio = vp.calls_used as f64 / uni.calls_used as f64;
            table.row(vec![
                name.into(),
                d.to_string(),
                format!("{tau:.0e}"),
                uni.calls_used.to_string(),
                vp.calls_used.to_string(),
                format!("{ratio:.2}x"),
                format!("{:.1e}", rel(&uni)),
                format!("{:.1e}", rel(&vp)),
            ]);
            let tag = format!("sampling_{name}_d{d}");
            emit_bench(&tag, "uniform_calls", uni.calls_used as f64, "calls");
            emit_bench(&tag, "vegas_plus_calls", vp.calls_used as f64, "calls");
            emit_bench(&tag, "calls_ratio", ratio, "x");
            emit_bench(&tag, "uniform_rel_err", rel(&uni), "rel");
            emit_bench(&tag, "vegas_plus_rel_err", rel(&vp), "rel");
            csv.row(vec![
                tag.clone(),
                "uniform_calls".into(),
                uni.calls_used.to_string(),
            ]);
            csv.row(vec![
                tag.clone(),
                "vegas_plus_calls".into(),
                vp.calls_used.to_string(),
            ]);
            csv.row(vec![tag, "calls_ratio".into(), format!("{ratio:.4}")]);
        }
        println!("{}", table.render());
    }

    // ---- Scheduler throughput (mixed multi-job workload) --------------
    // 16 independent jobs over the f1–f6 Genz suite, fixed work per job
    // (unreachable tau), time-sliced round-robin at a 2^18-call quantum.
    // Jobs/sec and total calls/sec per worker count are the serving
    // numbers the ROADMAP trajectory tracks.
    {
        println!("\nscheduler throughput: 16 mixed f1–f6 jobs, 2^18-call quantum:");
        let suite: &[(&str, usize)] = &[
            ("f1", 5),
            ("f2", 6),
            ("f3", 3),
            ("f4", 5),
            ("f5", 8),
            ("f6", 6),
        ];
        let mut table = Table::new(&["workers", "wall ms", "jobs/s", "Mcalls/s", "p95 ms"]);
        for workers in [1usize, 4, 8] {
            let mut sched = Scheduler::new(workers);
            sched.calls_budget(1 << 18);
            for i in 0..16u64 {
                let (name, d) = suite[i as usize % suite.len()];
                sched.submit(JobRequest::registry(
                    i,
                    name,
                    d,
                    JobConfig::default()
                        .with_maxcalls(1 << 15)
                        .with_plan(RunPlan::classic(8, 6, 1))
                        .with_tolerance(1e-12) // fixed work: run the whole plan
                        .with_seed(3000 + i as u32),
                ));
            }
            let (results, m) = sched.drain().expect("scheduler drain");
            assert_eq!(m.failures, 0, "bench workload must not fail");
            assert_eq!(results.len(), 16);
            table.row(vec![
                workers.to_string(),
                format!("{:.1}", m.wall_time * 1e3),
                format!("{:.2}", m.throughput),
                format!("{:.2}", m.calls_per_sec / 1e6),
                format!("{:.1}", m.latency_p95 * 1e3),
            ]);
            let tag = format!("scheduler_16jobs_w{workers}");
            emit_bench(&tag, "jobs_per_sec", m.throughput, "jobs/s");
            emit_bench(&tag, "calls_per_sec", m.calls_per_sec, "calls/s");
            emit_bench(&tag, "wall_ms", m.wall_time * 1e3, "ms");
            csv.row(vec![
                tag.clone(),
                "jobs_per_sec".into(),
                format!("{:.4}", m.throughput),
            ]);
            csv.row(vec![
                tag,
                "calls_per_sec".into(),
                format!("{:.1}", m.calls_per_sec),
            ]);
        }
        println!("{}", table.render());
    }

    // ---- Shard scaling (one integral, N shard workers) ----------------
    // One full iteration (adjust variant) through the sharded backend
    // at shards = threads = N: the parallelism axis is the shard span,
    // each span worker runs single-threaded. The result bytes are
    // identical at every N (rust/tests/shard_equivalence.rs); this
    // series is the wall-clock evidence that the split actually scales.
    {
        println!("\nshard scaling: one iteration split across N in-process shards:");
        let mut table = Table::new(&["integrand", "d", "shards", "ms/iter", "Mevals/s", "scaling"]);
        for (name, d) in [("f4", 8), ("f5", 8)] {
            let f = by_name(name, d).unwrap();
            let calls = 1 << 17;
            let layout = Layout::compute(d, calls, 50, 8).unwrap();
            let bins = Bins::uniform(d, 50);
            let mut base_ms = 0.0f64;
            for shards in [1usize, 2, 4, 8] {
                let mut backend = ShardedBackend::new(
                    f.clone(),
                    layout,
                    shards,
                    shards,
                    Sampling::Uniform,
                    None,
                )
                .unwrap();
                let stats = bench(opts, || {
                    black_box(backend.run(&bins, 1, 0, true).unwrap())
                });
                let ms = stats.median_ms();
                if shards == 1 {
                    base_ms = ms;
                }
                let scaling = base_ms / ms;
                let mevals = layout.calls() as f64 / (ms / 1e3) / 1e6;
                table.row(vec![
                    name.into(),
                    d.to_string(),
                    shards.to_string(),
                    format!("{ms:.2}"),
                    format!("{mevals:.2}"),
                    format!("{scaling:.2}x"),
                ]);
                let tag = format!("shard_{name}_d{d}_s{shards}");
                emit_bench(&tag, "ms", ms, "ms");
                emit_bench(&tag, "mevals_per_sec", mevals * 1e6, "evals/s");
                emit_bench(&tag, "scaling", scaling, "x");
                csv.row(vec![tag.clone(), "ms".into(), format!("{ms:.4}")]);
                csv.row(vec![tag, "scaling".into(), format!("{scaling:.4}")]);
            }
        }
        println!("{}", table.render());
    }

    // ---- Engine dispatch overhead (static vs trait object) ------------
    // The tentpole routed every native pass through the `Engine` trait;
    // the driver is generic (`EngineBackend<E>`) so the common case is
    // still static dispatch, but `Box<dyn Engine>` is supported for
    // runtime engine selection. This series pins how much the vtable
    // costs on a full V-Sample pass (expected: noise — one virtual call
    // per task range, amortized over ~10^5 evaluations).
    {
        println!("\nengine dispatch overhead: static vs Box<dyn Engine> (f4 d=8):");
        let f = by_name("f4", 8).unwrap();
        let calls = 1 << 17;
        let layout = Layout::compute(8, calls, 50, 8).unwrap();
        let bins = Bins::uniform(8, 50);
        let vopts = VSampleOpts {
            seed: 1,
            iteration: 0,
            adjust: true,
            threads: 1,
        };
        let mut static_engine = UniformEngine::new(layout);
        let t_static = bench(opts, || {
            black_box(static_engine.vsample(
                &*f,
                &bins,
                &vopts,
                FillPath::Simd,
                ExecPath::default(),
            ))
        });
        let mut dyn_engine: Box<dyn Engine> = Box::new(UniformEngine::new(layout));
        let t_dyn = bench(opts, || {
            black_box(dyn_engine.vsample(
                &*f,
                &bins,
                &vopts,
                FillPath::Simd,
                ExecPath::default(),
            ))
        });
        let overhead = t_dyn.median_ms() / t_static.median_ms();
        println!(
            "static {:.2} ms vs dyn {:.2} ms ({overhead:.3}x)",
            t_static.median_ms(),
            t_dyn.median_ms()
        );
        let tag = "dispatch_overhead_f4_d8";
        emit_bench(tag, "static_ms", t_static.median_ms(), "ms");
        emit_bench(tag, "dyn_ms", t_dyn.median_ms(), "ms");
        emit_bench(tag, "dyn_over_static", overhead, "x");
        // The gated ratio is the reciprocal: the regression checker
        // treats unit `x` as higher-is-better, so gate "how close dyn
        // stays to static" — growing vtable overhead drives it down.
        emit_bench(tag, "static_over_dyn", 1.0 / overhead, "x");
        csv.row(vec![
            tag.into(),
            "dyn_over_static".into(),
            format!("{overhead:.4}"),
        ]);
    }

    let _ = csv.write_csv("results/perf_microbench.csv");
    println!("\nseries written to results/perf_microbench.csv");
}
