//! Fig. 3 reproduction: speedup of m-Cubes1D over m-Cubes on the
//! symmetric integrands (f2, f4, f5) across precision levels.
//!
//! m-Cubes1D maintains one shared bin histogram/boundary set, so the
//! per-iteration adjustment work (and the paper's atomic-update
//! traffic) drops by a factor of d.
//! CSV: results/fig3_onedim.csv

use mcubes::api::{Integrator, RunPlan};
use mcubes::grid::GridMode;
use mcubes::integrands::by_name;
use mcubes::util::benchkit::{bench, BenchOpts};
use mcubes::util::table::{fmt_ms, Table};

fn main() {
    let full = std::env::var("MCUBES_BENCH_FULL").map(|v| v == "1").unwrap_or(false);
    let taus: &[f64] = if full { &[1e-3, 2e-4, 4e-5] } else { &[1e-3, 2e-4] };
    let cases = [("f2", 6, 1 << 15), ("f4", 8, 1 << 16), ("f5", 8, 1 << 15)];
    let opts = BenchOpts {
        warmup: 1,
        runs: if full { 7 } else { 3 },
        ..Default::default()
    }
    .quick_aware();

    println!("== Fig. 3: m-Cubes1D speedup on symmetric integrands ==\n");
    let mut table = Table::new(&["integrand", "tau", "m-Cubes", "m-Cubes1D", "speedup", "1d rel-true"]);
    let mut csv = Table::new(&["integrand", "dim", "tau", "mcubes_ms", "onedim_ms", "speedup"]);

    for (name, d, calls) in cases {
        let f = by_name(name, d).expect("integrand");
        let truth = f.true_value().unwrap();
        for &tau in taus {
            let mk = |mode: GridMode| {
                Integrator::new(f.clone())
                    .maxcalls(calls)
                    .tolerance(tau)
                    .plan(RunPlan::classic(20, 12, 2))
                    .seed(13)
                    .grid_mode(mode)
            };
            let per_axis_stats = bench(opts, || mk(GridMode::PerAxis).run().unwrap());
            let onedim_out = mk(GridMode::Shared1D).run().unwrap();
            let onedim_stats = bench(opts, || mk(GridMode::Shared1D).run().unwrap());
            let speedup = per_axis_stats.median_ms() / onedim_stats.median_ms().max(1e-9);
            let rel = ((onedim_out.integral - truth) / truth).abs();
            table.row(vec![
                format!("{name} d={d}"),
                format!("{tau:.0e}"),
                fmt_ms(per_axis_stats.median_ms()),
                fmt_ms(onedim_stats.median_ms()),
                format!("{speedup:.3}x"),
                format!("{rel:.1e}"),
            ]);
            csv.row(vec![
                name.into(),
                d.to_string(),
                format!("{tau:e}"),
                format!("{:.3}", per_axis_stats.median_ms()),
                format!("{:.3}", onedim_stats.median_ms()),
                format!("{speedup:.4}"),
            ]);
        }
    }
    println!("{}", table.render());
    println!("(paper shape: modest >1x speedups, varying by integrand/precision)");
    let _ = csv.write_csv("results/fig3_onedim.csv");
    println!("series written to results/fig3_onedim.csv");
}
