//! Fig. 1 reproduction: achieved relative error vs requested digits of
//! precision, many runs per cell, box-plot statistics per
//! (integrand, tau) — the paper's accuracy/honesty experiment.
//!
//! Default: 2 ladder rungs x 5 runs (tractable on a single-core box).
//! Set MCUBES_BENCH_FULL=1 for the paper-scale sweep (ladder to 1e-9
//! where convergence is feasible, 100 runs per cell).
//! CSV series land in results/fig1_accuracy.csv.

// Narrowing / float→int casts in this file are deliberate and
// audited by `cargo xtask lint` (MC001); see docs/invariants.md.
#![allow(clippy::cast_possible_truncation)]

use mcubes::api::{Integrator, RunPlan};
use mcubes::estimator::precision_ladder;
use mcubes::integrands::by_name;
use mcubes::report::{AccuracyCell, BoxStats};
use mcubes::util::table::Table;

fn main() {
    let full = std::env::var("MCUBES_BENCH_FULL").map(|v| v == "1").unwrap_or(false);
    let runs = if full { 100 } else { 5 };
    let rungs = if full { 6 } else { 2 };
    // The paper's Fig. 1 panel: f2@6, f3@3, f3@8, f4@5, f4@8, f5@8, f6@6
    // (f1 omitted as in the paper — no VEGAS variant converges on it).
    let cases = [
        ("f2", 6),
        ("f3", 3),
        ("f3", 8),
        ("f4", 5),
        ("f4", 8),
        ("f5", 8),
        ("f6", 6),
    ];
    let ladder: Vec<f64> = precision_ladder().into_iter().take(rungs).collect();

    println!("== Fig. 1: achieved relative error vs requested precision ==");
    println!("   ({} runs per cell; orange-dot analogue = requested tau)\n", runs);
    let mut table = Table::new(&[
        "integrand", "digits", "tau", "q1", "median", "q3", "whisk-hi", "outliers", "conv",
    ]);
    let mut csv = Table::new(&[
        "integrand", "dim", "tau", "digits", "n", "min", "q1", "median", "q3", "max", "converged",
    ]);

    for (name, d) in cases {
        let f = by_name(name, d).expect("integrand");
        let truth = f.true_value().unwrap();
        for &tau in &ladder {
            let mut achieved = Vec::with_capacity(runs);
            let mut conv = 0usize;
            for r in 0..runs {
                // Escalate calls x4 up to 6 times (2^14 -> 2^26 ceiling)
                let run = Integrator::new(f.clone())
                    .maxcalls(1 << 14)
                    .tolerance(tau)
                    .plan(RunPlan::classic(20, 12, 2))
                    .seed((1000 + 77 * r) as u32)
                    .escalate(if full { 6 } else { 4 }, 4)
                    .run();
                if let Ok(out) = run {
                    if out.converged {
                        conv += 1;
                        achieved.push(((out.integral - truth) / truth).abs());
                    }
                }
            }
            let cell = AccuracyCell {
                integrand: name.into(),
                dim: d,
                tau_rel: tau,
                digits: -tau.log10(),
                achieved: BoxStats::from_samples(&achieved),
                runs_converged: conv,
                runs_total: runs,
            };
            let b = &cell.achieved;
            let (_, hi) = b.whiskers();
            table.row(vec![
                format!("{name} d={d}"),
                format!("{:.1}", cell.digits),
                format!("{tau:.1e}"),
                format!("{:.1e}", b.q1),
                format!("{:.1e}", b.median),
                format!("{:.1e}", b.q3),
                format!("{:.1e}", hi),
                b.outliers.len().to_string(),
                format!("{conv}/{runs}"),
            ]);
            csv.row(vec![
                name.into(),
                d.to_string(),
                format!("{tau:e}"),
                format!("{}", cell.digits),
                b.n.to_string(),
                format!("{:e}", b.min),
                format!("{:e}", b.q1),
                format!("{:e}", b.median),
                format!("{:e}", b.q3),
                format!("{:e}", b.max),
                conv.to_string(),
            ]);
            // If this rung already failed to converge for most runs,
            // deeper rungs won't do better (paper stops the ladder too).
            if conv * 2 < runs {
                break;
            }
        }
    }
    println!("{}", table.render());
    println!("(paper shape: boxes straddle/undercut tau, shrinking spread at higher digits)");
    let _ = csv.write_csv("results/fig1_accuracy.csv");
    println!("series written to results/fig1_accuracy.csv");
}
