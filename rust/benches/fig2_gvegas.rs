//! Fig. 2 reproduction: m-Cubes vs gVegas time-to-converge per
//! integrand and precision level.
//!
//! The paper's claim: m-Cubes is up to an order of magnitude faster;
//! gVegas (a) stages every function evaluation through a host buffer,
//! (b) builds the importance histogram on the host, and (c) is capped
//! in samples-per-iteration by device memory, so it needs many more
//! (weaker) iterations and often fails to converge at all — the
//! paper's "missing entries". `gvegas_sim` reproduces these mechanisms
//! with identical VEGAS math and Philox stream.
//!
//! Semantics follow the paper: each algorithm runs until it converges
//! to tau or exhausts its escalation budget; non-converged cells are
//! reported as missing ("—"). CSV: results/fig2_gvegas.csv

use mcubes::api::{Integrator, RunPlan};
use mcubes::baselines::{gvegas_integrate, GvegasConfig};
use mcubes::integrands::by_name;
use mcubes::util::table::{fmt_ms, Table};

fn main() {
    let full = std::env::var("MCUBES_BENCH_FULL").map(|v| v == "1").unwrap_or(false);
    let taus: &[f64] = if full {
        &[1e-3, 2e-4, 4e-5]
    } else {
        &[1e-3, 2e-4]
    };
    // (name, dim, base calls per iteration for m-Cubes)
    let cases = [
        ("f2", 6, 1 << 15),
        ("f3", 3, 1 << 14),
        ("f4", 5, 1 << 16),
        ("f5", 8, 1 << 15),
        ("f6", 6, 1 << 16),
    ];
    println!("== Fig. 2: m-Cubes vs gVegas time-to-converge ==");
    println!("   ('—' = did not converge, the paper's missing entries)\n");
    let mut table = Table::new(&["integrand", "tau", "m-Cubes", "gVegas-sim", "speedup"]);
    let mut csv = Table::new(&["integrand", "dim", "tau", "mcubes_ms", "gvegas_ms", "speedup"]);

    for (name, d, base_calls) in cases {
        let f = by_name(name, d).expect("integrand");
        for &tau in taus {
            // m-Cubes: escalate per-iteration budget x4 until converged.
            let mc = Integrator::new(f.clone())
                .maxcalls(base_calls)
                .tolerance(tau)
                .plan(RunPlan::classic(15, 10, 2))
                .seed(3)
                .escalate(5, 4)
                .run()
                .expect("mcubes");

            // gVegas: same total budget ambitions, but per-iteration
            // samples capped by "device memory" (2^14 evaluations).
            let gv = gvegas_integrate(
                &*f,
                &GvegasConfig {
                    maxcalls: mc.calls_used.max(base_calls), // same total budget
                    tau_rel: tau,
                    itmax: 15,
                    ita: 10,
                    seed: 3,
                    launch_cap: 1 << 14,
                    ..Default::default()
                },
            );

            let mc_cell = if mc.converged {
                fmt_ms(mc.total_time * 1e3)
            } else {
                "—".into()
            };
            let gv_cell = if gv.converged {
                fmt_ms(gv.total_time * 1e3)
            } else {
                "—".into()
            };
            let speedup = if mc.converged && gv.converged {
                format!("{:.2}x", gv.total_time / mc.total_time.max(1e-12))
            } else if mc.converged {
                "mc only".into()
            } else {
                "-".into()
            };
            table.row(vec![
                format!("{name} d={d}"),
                format!("{tau:.0e}"),
                mc_cell,
                gv_cell,
                speedup.clone(),
            ]);
            csv.row(vec![
                name.into(),
                d.to_string(),
                format!("{tau:e}"),
                if mc.converged { format!("{:.3}", mc.total_time * 1e3) } else { "nan".into() },
                if gv.converged { format!("{:.3}", gv.total_time * 1e3) } else { "nan".into() },
                speedup,
            ]);
        }
    }
    println!("{}", table.render());
    println!(
        "(paper shape: m-Cubes converges everywhere it should; gVegas trails or\n\
         goes missing as precision rises — its per-launch sample cap starves it)"
    );
    let _ = csv.write_csv("results/fig2_gvegas.csv");
    println!("series written to results/fig2_gvegas.csv");
}
