"""Philox4x32-10 correctness: published KAT vectors + stream properties."""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from compile import philox


class TestKAT:
    """Known-answer tests against the Random123 published vectors."""

    def test_zeros(self):
        r = philox.philox4x32(0, 0, 0, 0, 0, 0)
        assert [int(x) for x in r] == [
            0x6627E8D5, 0xE169C58D, 0xBC57AC4C, 0x9B00DBD8]

    def test_ones_complement(self):
        f = 0xFFFFFFFF
        r = philox.philox4x32(f, f, f, f, f, f)
        assert [int(x) for x in r] == [
            0x408F276D, 0x41C83B0E, 0xA20BC7C6, 0x6D5451FD]

    def test_vectorized_matches_scalar(self):
        c0 = jnp.arange(64, dtype=jnp.uint32)
        rv = philox.philox4x32(c0, 1, 2, 3, 4, 5)
        for i in [0, 13, 63]:
            rs = philox.philox4x32(i, 1, 2, 3, 4, 5)
            for a, b in zip(rv, rs):
                assert int(a[i]) == int(b)


class TestUniforms:
    def test_open_interval(self):
        u = philox.uniforms(jnp.arange(10000, dtype=jnp.uint32), 0, 1, 8)
        assert float(u.min()) > 0.0
        assert float(u.max()) < 1.0

    def test_mean_and_var(self):
        u = np.asarray(
            philox.uniforms(jnp.arange(200000, dtype=jnp.uint32), 0, 17, 4))
        assert abs(u.mean() - 0.5) < 2e-3
        assert abs(u.var() - 1.0 / 12.0) < 2e-3

    def test_iteration_decorrelates(self):
        idx = jnp.arange(4096, dtype=jnp.uint32)
        u0 = np.asarray(philox.uniforms(idx, 0, 9, 3))
        u1 = np.asarray(philox.uniforms(idx, 1, 9, 3))
        assert not np.allclose(u0, u1)
        corr = np.corrcoef(u0.ravel(), u1.ravel())[0, 1]
        assert abs(corr) < 0.05

    def test_seed_decorrelates(self):
        idx = jnp.arange(4096, dtype=jnp.uint32)
        u0 = np.asarray(philox.uniforms(idx, 2, 1, 3))
        u1 = np.asarray(philox.uniforms(idx, 2, 2, 3))
        assert not np.allclose(u0, u1)

    def test_deterministic(self):
        idx = jnp.arange(128, dtype=jnp.uint32)
        a = np.asarray(philox.uniforms(idx, 5, 6, 7))
        b = np.asarray(philox.uniforms(idx, 5, 6, 7))
        np.testing.assert_array_equal(a, b)

    @given(ndim=st.integers(1, 16), n=st.integers(1, 257))
    @settings(max_examples=20, deadline=None)
    def test_shapes(self, ndim, n):
        u = philox.uniforms(jnp.arange(n, dtype=jnp.uint32), 0, 1, ndim)
        assert u.shape == (n, ndim)
        assert u.dtype == jnp.float64

    def test_extra_words_discarded_consistently(self):
        """First 4 dims of a 6-dim draw == the 4-dim draw (same blocks)."""
        idx = jnp.arange(100, dtype=jnp.uint32)
        u6 = np.asarray(philox.uniforms(idx, 3, 11, 6))
        u4 = np.asarray(philox.uniforms(idx, 3, 11, 4))
        np.testing.assert_array_equal(u6[:, :4], u4)
