"""Kernel-vs-oracle: the CORE correctness signal for L1.

The Pallas kernel must reproduce the pure-jnp oracle for every integrand,
layout, variant, and bin configuration — same Philox stream, same change
of variables, same reductions (up to fp summation order across blocks).
"""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from compile import integrands, model, sampling
from compile.kernels import ref
from compile.layout import compute_layout
from compile.model import ModelSpec


def run_both(name, dim, calls, nb=20, nblocks=4, seed=9, it=0,
             bins=None, adjust=True, hist_mode="scatter"):
    spec = ModelSpec(name, dim, calls, nb=nb, nblocks=nblocks,
                     adjust=adjust, hist_mode=hist_mode)
    fn, layout, _ = model.build(spec)
    ispec = integrands.get(name)
    tables = integrands.make_tables(ispec)
    if bins is None:
        bins = ref.uniform_bins(dim, nb)
    lo = jnp.full(dim, ispec.lo)
    hi = jnp.full(dim, ispec.hi)
    seed_it = jnp.array([seed, it], dtype=jnp.uint32)
    args = [bins, lo, hi, seed_it] + ([tables] if tables is not None else [])
    got = fn(*args)
    want = ref.vsample_ref(ispec.fn, tables, bins, lo, hi, seed, it, layout,
                           adjust=adjust)
    return got, want, layout


CASES = [("f1", 5), ("f2", 6), ("f3", 3), ("f3", 8), ("f4", 5),
         ("f5", 8), ("f6", 6), ("fA", 6), ("fB", 9), ("cosmo", 6)]


class TestKernelVsOracle:
    @pytest.mark.parametrize("name,dim", CASES)
    def test_adjust_variant(self, name, dim):
        (res, c), (i_ref, v_ref, c_ref), _ = run_both(name, dim, 4096)
        np.testing.assert_allclose(float(res[0]), float(i_ref), rtol=1e-12)
        np.testing.assert_allclose(float(res[1]), float(v_ref), rtol=1e-12)
        np.testing.assert_allclose(np.asarray(c), np.asarray(c_ref),
                                   rtol=1e-10, atol=1e-300)

    @pytest.mark.parametrize("name,dim", [("f4", 5), ("fB", 9)])
    def test_no_adjust_variant(self, name, dim):
        (res,), (i_ref, v_ref, _), _ = run_both(name, dim, 4096, adjust=False)
        np.testing.assert_allclose(float(res[0]), float(i_ref), rtol=1e-12)
        np.testing.assert_allclose(float(res[1]), float(v_ref), rtol=1e-12)

    def test_onehot_hist_matches_scatter(self):
        (res_s, c_s), _, _ = run_both("f4", 5, 4096, hist_mode="scatter")
        (res_o, c_o), _, _ = run_both("f4", 5, 4096, hist_mode="onehot")
        np.testing.assert_allclose(np.asarray(c_s), np.asarray(c_o),
                                   rtol=1e-10)
        np.testing.assert_allclose(np.asarray(res_s), np.asarray(res_o),
                                   rtol=1e-12)

    def test_nonuniform_bins(self):
        nb = 20
        edges = (jnp.arange(1, nb + 1) / nb) ** 2.0
        bins = jnp.tile(edges, (5, 1))
        (res, c), (i_ref, v_ref, c_ref), _ = run_both(
            "f4", 5, 4096, bins=bins)
        np.testing.assert_allclose(float(res[0]), float(i_ref), rtol=1e-12)
        np.testing.assert_allclose(np.asarray(c), np.asarray(c_ref),
                                   rtol=1e-10, atol=1e-300)

    def test_block_count_invariance(self):
        """Partials must sum to the same result for any grid split."""
        (r1, _), _, _ = run_both("f2", 6, 4096, nblocks=1)
        (r4, _), _, _ = run_both("f2", 6, 4096, nblocks=4)
        (r7, _), _, _ = run_both("f2", 6, 4096, nblocks=7)
        np.testing.assert_allclose(np.asarray(r1), np.asarray(r4), rtol=1e-12)
        np.testing.assert_allclose(np.asarray(r1), np.asarray(r7), rtol=1e-12)

    def test_seed_changes_result(self):
        (r1, _), _, _ = run_both("f4", 5, 4096, seed=1)
        (r2, _), _, _ = run_both("f4", 5, 4096, seed=2)
        assert float(r1[0]) != float(r2[0])

    def test_iteration_changes_result(self):
        (r1, _), _, _ = run_both("f4", 5, 4096, it=0)
        (r2, _), _, _ = run_both("f4", 5, 4096, it=1)
        assert float(r1[0]) != float(r2[0])

    @given(dim=st.integers(2, 8),
           logc=st.integers(9, 13),
           nb=st.sampled_from([10, 20, 50]),
           nblocks=st.integers(1, 8))
    @settings(max_examples=12, deadline=None)
    def test_hypothesis_sweep_f5(self, dim, logc, nb, nblocks):
        """Shape/layout sweep: kernel == oracle on arbitrary layouts."""
        (res, c), (i_ref, v_ref, c_ref), layout = run_both(
            "f5", dim, 1 << logc, nb=nb, nblocks=nblocks)
        assert c.shape == (dim, nb)
        np.testing.assert_allclose(float(res[0]), float(i_ref), rtol=1e-11)
        np.testing.assert_allclose(float(res[1]), float(v_ref), rtol=1e-11)
        np.testing.assert_allclose(np.asarray(c), np.asarray(c_ref),
                                   rtol=1e-9, atol=1e-300)


class TestEstimateSanity:
    """First-iteration estimates (uniform grid) are plain stratified MC:
    they must land within a few sigma of the true value for smooth fns."""

    @pytest.mark.parametrize("name,dim,calls", [
        ("f5", 4, 1 << 14), ("f3", 3, 1 << 14), ("cosmo", 6, 1 << 14),
    ])
    def test_first_iteration_within_5_sigma(self, name, dim, calls):
        (res, _), _, _ = run_both(name, dim, calls, nb=50, seed=3)
        true = integrands.true_value(name, dim)
        i, var = float(res[0]), float(res[1])
        assert abs(i - true) < 5.0 * np.sqrt(var) + 1e-12

    def test_variance_positive(self):
        (res, _), _, _ = run_both("f4", 5, 4096)
        assert float(res[1]) > 0.0


class TestLayout:
    def test_paper_layout_rule(self):
        lay = compute_layout(5, 1 << 14)
        assert lay.g == int((lay.calls and (1 << 14) / 2) ** (1 / 5)) or lay.g >= 1
        assert lay.m == lay.g ** 5
        assert lay.p >= 2
        assert lay.m * lay.p == lay.calls

    def test_cubes_cover_calls(self):
        for d in (1, 2, 3, 6, 10):
            lay = compute_layout(d, 100000)
            assert lay.p == max(2, 100000 // lay.m)
            assert lay.cpb * lay.nblocks >= lay.m

    def test_g_maximal(self):
        lay = compute_layout(3, 16384)
        assert (lay.g + 1) ** 3 > 16384 // 2

    def test_rejects_bad_args(self):
        with pytest.raises(ValueError):
            compute_layout(0, 1000)
        with pytest.raises(ValueError):
            compute_layout(3, 2)


class TestSamplingPrimitives:
    def test_cube_coords_roundtrip(self):
        g, d = 7, 4
        idx = jnp.arange(g ** d, dtype=jnp.int64)
        coords = np.asarray(sampling.cube_coords(idx, g, d))
        # re-encode
        enc = sum(coords[:, i] * g ** i for i in range(d))
        np.testing.assert_array_equal(enc, np.arange(g ** d))

    def test_transform_uniform_bins_is_affine(self):
        """With uniform bins the VEGAS map must reduce to identity."""
        d, nb, g = 3, 10, 4
        n = 1000
        u = jnp.asarray(np.random.RandomState(0).rand(n, d))
        coords = jnp.asarray(np.random.RandomState(1).randint(0, g, (n, d)),
                             dtype=jnp.float64)
        bins = ref.uniform_bins(d, nb)
        lo = jnp.zeros(d)
        hi = jnp.ones(d)
        x, jac, b = sampling.transform(u, coords, bins, lo, hi, nb, g)
        z = (coords + u) / g
        np.testing.assert_allclose(np.asarray(x), np.asarray(z), atol=1e-12)
        np.testing.assert_allclose(np.asarray(jac), 1.0, rtol=1e-12)

    def test_transform_jacobian_integrates_to_volume(self):
        """E[jac] over uniform samples = total volume for any bins."""
        d, nb, g = 2, 16, 8
        n = 200000
        rng = np.random.RandomState(2)
        u = jnp.asarray(rng.rand(n, d))
        coords = jnp.asarray(rng.randint(0, g, (n, d)), dtype=jnp.float64)
        edges = (np.arange(1, nb + 1) / nb) ** 1.5
        edges[-1] = 1.0
        bins = jnp.asarray(np.tile(edges, (d, 1)))
        lo = jnp.asarray([0.0, -2.0])
        hi = jnp.asarray([3.0, 2.0])
        x, jac, _ = sampling.transform(u, coords, bins, lo, hi, nb, g)
        vol = 3.0 * 4.0
        assert float(jnp.mean(jac)) == pytest.approx(vol, rel=5e-2)
        assert np.all(np.asarray(x) >= np.array([0.0, -2.0]) - 1e-12)
        assert np.all(np.asarray(x) <= np.array([3.0, 2.0]) + 1e-12)

    def test_histogram_total_mass(self):
        """sum(C) per axis == sum(v^2) exactly."""
        n, d, nb = 5000, 3, 25
        rng = np.random.RandomState(3)
        v = jnp.asarray(rng.randn(n))
        b = jnp.asarray(rng.randint(0, nb, (n, d)), dtype=jnp.int32)
        c = np.asarray(sampling.bin_histogram(v, b, d, nb))
        for ax in range(d):
            assert c[ax].sum() == pytest.approx(float(jnp.sum(v * v)),
                                                rel=1e-12)

    def test_histogram_onehot_equals_scatter(self):
        n, d, nb = 3000, 4, 30
        rng = np.random.RandomState(4)
        v = jnp.asarray(rng.randn(n))
        b = jnp.asarray(rng.randint(0, nb, (n, d)), dtype=jnp.int32)
        c1 = np.asarray(sampling.bin_histogram(v, b, d, nb))
        c2 = np.asarray(sampling.bin_histogram_onehot(v, b, d, nb, chunk=512))
        np.testing.assert_allclose(c1, c2, rtol=1e-12)

    def test_reduce_cubes_known_values(self):
        # 2 cubes x 2 samples: v = [1,3, 2,2], m=2, p=2
        v = jnp.asarray([1.0, 3.0, 2.0, 2.0])
        i, var = sampling.reduce_cubes(v, p=2, m=2)
        # means: 2, 2 -> I = (2+2)/2 = 2
        assert float(i) == pytest.approx(2.0)
        # cube1 sample var: ((1-2)^2+(3-2)^2)/(2-1)/2 = ... s2/p - mean^2 = (1+9)/2-4=1
        # var_t = 1/(p-1) = 1 ; cube2: 0 -> Var = (1+0)/m^2 = 0.25
        assert float(var) == pytest.approx(0.25)
