"""Integrand registry: closed-form spot values + true-value identities."""

import math

import jax.numpy as jnp
import numpy as np
import pytest

from compile import integrands


def pt(*coords):
    return jnp.asarray([coords], dtype=jnp.float64)


def val(arr):
    """Scalar value of a length-1 result batch."""
    return float(np.asarray(arr)[0])


class TestSpotValues:
    def test_f1_zero(self):
        assert val(integrands.f1(pt(0, 0, 0))) == pytest.approx(1.0)

    def test_f1_known(self):
        # cos(1*x1 + 2*x2) at (pi/2, pi/4) -> cos(pi) = -1
        v = val(integrands.f1(pt(math.pi / 2, math.pi / 4)))
        assert v == pytest.approx(-1.0)

    def test_f2_center_peak(self):
        d = 4
        v = val(integrands.f2(jnp.full((1, d), 0.5)))
        assert v == pytest.approx(2500.0 ** d)

    def test_f3_origin(self):
        assert val(integrands.f3(pt(0, 0, 0))) == pytest.approx(1.0)

    def test_f4_center(self):
        assert val(integrands.f4(jnp.full((1, 6), 0.5))) == pytest.approx(1.0)

    def test_f5_center(self):
        assert val(integrands.f5(jnp.full((1, 8), 0.5))) == pytest.approx(1.0)

    def test_f6_discontinuity(self):
        # d=2: cutoff at x1 < 0.4, x2 < 0.5
        inside = val(integrands.f6(pt(0.39, 0.49)))
        outside = val(integrands.f6(pt(0.41, 0.49)))
        assert inside == pytest.approx(math.exp(5 * 0.39 + 6 * 0.49))
        assert outside == 0.0

    def test_fA_zero(self):
        assert val(integrands.fA(jnp.zeros((1, 6)))) == pytest.approx(0.0)

    def test_fB_center(self):
        v = val(integrands.fB_consistent(jnp.zeros((1, 9))))
        assert v == pytest.approx((2 * math.pi * 0.01) ** -4.5)

    def test_cosmo_uses_tables(self):
        spec = integrands.get("cosmo")
        tables = integrands.make_tables(spec)
        x = jnp.full((1, 6), 0.25)
        v1 = val(integrands.cosmo(x, tables))
        v2 = val(integrands.cosmo(x, tables * 2.0))
        assert v2 == pytest.approx(4.0 * v1)  # both tables scale


class TestTrueValues:
    """Validate closed forms against brute-force quadrature in low dim."""

    def quad(self, fn, d, n=400, lo=0.0, hi=1.0, tables=None):
        xs = np.linspace(lo, hi, n + 1)
        xs = 0.5 * (xs[1:] + xs[:-1])
        grids = np.meshgrid(*([xs] * d), indexing="ij")
        pts = jnp.asarray(np.stack([g.ravel() for g in grids], axis=-1))
        vals = np.asarray(integrands.REGISTRY[fn].fn(pts, tables))
        return vals.mean() * (hi - lo) ** d

    @pytest.mark.parametrize("name,d,tol", [
        ("f1", 2, 1e-4), ("f3", 2, 1e-3), ("f5", 2, 1e-4), ("f6", 2, 1e-2),
    ])
    def test_quadrature_match(self, name, d, tol):
        got = self.quad(name, d)
        want = integrands.true_value(name, d)
        assert got == pytest.approx(want, rel=tol)

    def test_f2_quadrature(self):
        # Sharp peak: use many points in 1-D and the product structure.
        got_1d = self.quad("f2", 1, n=200000)
        want_1d = 50.0 * 2.0 * math.atan(25.0)
        assert got_1d == pytest.approx(want_1d, rel=1e-4)

    def test_f4_quadrature_1d(self):
        got = self.quad("f4", 1, n=100000)
        assert got == pytest.approx(
            integrands.true_value("f4", 1), rel=1e-6)

    def test_fA_true_value_matches_paper(self):
        # Paper Table 1: -49.165073
        assert integrands.true_value("fA", 6) == pytest.approx(
            -49.165073, abs=1e-5)

    def test_fB_true_value_near_one(self):
        assert integrands.true_value("fB", 9) == pytest.approx(1.0, abs=1e-9)

    def test_f3_closed_form_dim1(self):
        # d=1: int (1+x)^-2 = 1/2
        assert integrands.true_value("f3", 1) == pytest.approx(0.5)

    def test_cosmo_true_value_stable(self):
        a = integrands.cosmo_true_value(50001)
        b = integrands.cosmo_true_value(100001)
        assert a == pytest.approx(b, rel=1e-7)


class TestRegistry:
    def test_all_names_resolve(self):
        for name in integrands.REGISTRY:
            spec = integrands.get(name)
            assert spec.name == name

    def test_unknown_raises(self):
        with pytest.raises(KeyError):
            integrands.get("nope")

    def test_symmetric_flags(self):
        assert integrands.get("f4").symmetric
        assert integrands.get("f2").symmetric
        assert integrands.get("f5").symmetric
        assert not integrands.get("f3").symmetric
        assert not integrands.get("f6").symmetric
