"""Core V-Sample math, shared verbatim by the Pallas kernel and the oracle.

Everything here is pure jnp on explicit arrays, so the same code runs
inside the Pallas kernel body (on values loaded from refs) and in the
pure-jnp reference (`kernels/ref.py`). The Rust native engine
(`rust/src/engine/`) reimplements the identical math; cross-layer tests
pin them together.

Geometry recap (DESIGN.md §VEGAS math): the unit hypercube is cut into
`g` intervals per axis -> `m = g^d` stratification sub-cubes, and
independently into `nb` *importance* bins per axis with right edges
`bins[d, nb]` (monotone, ending at 1.0). A sample is placed uniformly in
its sub-cube, located within an importance bin, then warped by the bin's
width (the VEGAS change of variables) and finally affinely mapped to the
user's integration box [lo, hi]^d.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from . import philox


def cube_coords(cube_idx: jnp.ndarray, g: int, d: int) -> jnp.ndarray:
    """Decode flat sub-cube index -> (N, d) integer lattice coordinates.

    Digit i (axis i) is `(cube // g^i) % g`; identical decode order in
    `rust/src/strat/mod.rs`.
    """
    cols = []
    idx = cube_idx.astype(jnp.int64)
    for _ in range(d):
        cols.append((idx % g).astype(jnp.float64))
        idx = idx // g
    return jnp.stack(cols, axis=-1)


def transform(u, coords, bins, lo, hi, nb: int, g: int):
    """VEGAS change of variables for a batch of samples.

    u      : (N, d) uniforms in (0,1) — position within the sub-cube
    coords : (N, d) sub-cube lattice coordinates (float)
    bins   : (d, nb) importance-bin right edges in unit space
    lo, hi : (d,) integration box

    Returns (x, jac, b): points in integration space (N, d), the per-
    sample Jacobian (N,), and the per-axis bin index (N, d) int32.
    """
    z = (coords + u) / g                    # stratified point, unit space
    loc = z * nb                            # importance-bin coordinate
    b = jnp.clip(jnp.floor(loc).astype(jnp.int32), 0, nb - 1)
    right = jnp.take_along_axis(bins, b.T, axis=1).T
    left_idx = jnp.maximum(b - 1, 0)
    left_raw = jnp.take_along_axis(bins, left_idx.T, axis=1).T
    left = jnp.where(b > 0, left_raw, 0.0)
    w = right - left                        # bin widths
    xt = left + (loc - b) * w               # warped unit-space coordinate
    jac = jnp.prod(nb * w, axis=-1) * jnp.prod(hi - lo)
    x = lo + xt * (hi - lo)
    return x, jac, b


def draw_uniforms(cube_idx, sample_in_cube, p: int, iteration, seed, d: int):
    """Philox draws for sample `k` of cube `t`: globally-unique index t*p+k."""
    sidx = (cube_idx.astype(jnp.int64) * p + sample_in_cube.astype(jnp.int64))
    return philox.uniforms(sidx.astype(jnp.uint32), iteration, seed, d)


def reduce_cubes(v: jnp.ndarray, p: int, m: int):
    """Per-cube stratified estimate + variance (DESIGN.md §VEGAS math).

    v : (ncubes*p,) sample values f(x)*jac, zeroed for padded cubes.
    Returns (I_partial, Var_partial) summed over the cubes present.
    """
    vc = v.reshape(-1, p)
    s1 = jnp.sum(vc, axis=1)
    s2 = jnp.sum(vc * vc, axis=1)
    mean = s1 / p
    # Sample variance of the p draws; clamp fp negatives.
    var = jnp.maximum(s2 / p - mean * mean, 0.0) / (p - 1)
    i_partial = jnp.sum(mean) / m
    var_partial = jnp.sum(var) / (m * m)
    return i_partial, var_partial


def bin_histogram(v: jnp.ndarray, b: jnp.ndarray, d: int, nb: int):
    """Bin contributions C[axis, bin] = sum of v^2 (paper: I_k^2).

    Scatter-add (segment_sum) per axis — the CPU/interpret realization of
    the paper's atomicAdd histogram. The TPU-faithful realization is a
    one-hot MXU contraction; see `bin_histogram_onehot`.
    """
    v2 = v * v
    rows = [jax.ops.segment_sum(v2, b[:, i], num_segments=nb) for i in range(d)]
    return jnp.stack(rows)


def bin_histogram_onehot(v: jnp.ndarray, b: jnp.ndarray, d: int, nb: int,
                         chunk: int = 2048):
    """One-hot contraction histogram — MXU-shaped, VMEM-tiled.

    C[i, :] = onehot(b[:, i])^T @ v^2 computed in sample chunks of
    `chunk` so the (chunk, nb) one-hot staging buffer stays inside the
    VMEM budget (DESIGN.md §Perf-model). Numerically identical to
    `bin_histogram` up to summation order.
    """
    n = v.shape[0]
    v2 = v * v
    c = jnp.zeros((d, nb), dtype=v.dtype)
    ar = jnp.arange(nb, dtype=jnp.int32)
    for s in range(0, n, chunk):
        e = min(s + chunk, n)
        onehot = (b[s:e, :, None] == ar[None, None, :]).astype(v.dtype)
        c = c + jnp.einsum("n,ndk->dk", v2[s:e], onehot)
    return c
