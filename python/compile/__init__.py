"""Build-time compile package: L1 Pallas kernels + L2 JAX model + AOT lowering.

Python in this repo runs only at artifact-build time (`make artifacts`);
the Rust coordinator executes the lowered HLO via PJRT at runtime.

Double precision is mandatory for VEGAS (relative errors down to 1e-9),
so x64 is enabled package-wide before any jax arrays are created.
"""

import jax

jax.config.update("jax_enable_x64", True)
