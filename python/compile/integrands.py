"""Integrand registry — the paper's evaluation suite, in jnp.

Each integrand is a pure function `(x, tables) -> f` where `x` has shape
(N, d) in *integration-space* coordinates and `tables` is an optional
(T, K) float64 array of runtime state (interpolation tables) — `None`
for closed-form integrands. The same registry exists in Rust
(`rust/src/integrands/`) for the CPU baselines; names must match.

The suite (paper eq. 1-8):
  f1..f6 : the standard test suite (oscillatory, product peak, corner
           peak, Gaussian, C0, discontinuous), parameterized by dim.
  fA     : sin(sum x) over (0,10)^6            [ZMC comparison, eq. 7]
  fB     : 9-D narrow Gaussian over (-1,1)^9   [ZMC comparison, eq. 8]
  cosmo  : 6-D stateful integrand whose evaluation reads two runtime
           interpolation tables (stand-in for the paper's cosmology
           integrand with tabular state, section 6.1).

`true_value(name, d)` returns the analytic/semi-analytic reference used
by the accuracy experiments (Fig. 1).
"""

from __future__ import annotations

import math
from dataclasses import dataclass
from itertools import combinations
from typing import Callable, Optional

import jax.numpy as jnp

# ---------------------------------------------------------------------------
# Integrand definitions (vectorized over rows of x).
# ---------------------------------------------------------------------------


def f1(x, tables=None):
    """Oscillatory: cos(sum_i i * x_i)."""
    d = x.shape[-1]
    coef = jnp.arange(1, d + 1, dtype=x.dtype)
    return jnp.cos(x @ coef)


def f2(x, tables=None):
    """Product peak: prod_i (1/50^2 + (x_i - 1/2)^2)^-1."""
    a = 1.0 / (50.0 * 50.0)
    return jnp.prod(1.0 / (a + (x - 0.5) ** 2), axis=-1)


def f3(x, tables=None):
    """Corner peak: (1 + sum_i i*x_i)^(-d-1)."""
    d = x.shape[-1]
    coef = jnp.arange(1, d + 1, dtype=x.dtype)
    return (1.0 + x @ coef) ** (-(d + 1.0))


def f4(x, tables=None):
    """Gaussian: exp(-625 * sum_i (x_i - 1/2)^2)."""
    return jnp.exp(-625.0 * jnp.sum((x - 0.5) ** 2, axis=-1))


def f5(x, tables=None):
    """C0-continuous: exp(-10 * sum_i |x_i - 1/2|)."""
    return jnp.exp(-10.0 * jnp.sum(jnp.abs(x - 0.5), axis=-1))


def f6(x, tables=None):
    """Discontinuous: exp(sum_i (i+4) x_i) if all x_i < (3+i)/10 else 0."""
    d = x.shape[-1]
    i = jnp.arange(1, d + 1, dtype=x.dtype)
    inside = jnp.all(x < (3.0 + i) / 10.0, axis=-1)
    return jnp.where(inside, jnp.exp(x @ (i + 4.0)), 0.0)


def fA(x, tables=None):
    """sin(sum x) — evaluated over (0,10)^6 in the paper (eq. 7)."""
    return jnp.sin(jnp.sum(x, axis=-1))


def _interp1d(table_row, xi, lo, hi):
    """Linear interpolation of `table_row` (K knots, uniform on [lo,hi])."""
    k = table_row.shape[0]
    t = (xi - lo) / (hi - lo) * (k - 1)
    t = jnp.clip(t, 0.0, k - 1.000001)
    i0 = jnp.floor(t).astype(jnp.int32)
    frac = t - i0
    v0 = jnp.take(table_row, i0)
    v1 = jnp.take(table_row, i0 + 1)
    return v0 + frac * (v1 - v0)


def cosmo(x, tables):
    """Stateful 6-D integrand exercising runtime interpolation tables.

    f(x) = T0(x0) * T1(x1) * exp(-(x2^2+x3^2)) * (1 + 0.5*x4*x5)

    T0, T1 are runtime-loaded 1-D tables on uniform knots over [0,1]
    (rows 0 and 1 of `tables`). This mirrors the paper's cosmology
    integrand, whose cost is dominated by table lookups.
    """
    t0 = _interp1d(tables[0], x[:, 0], 0.0, 1.0)
    t1 = _interp1d(tables[1], x[:, 1], 0.0, 1.0)
    gauss = jnp.exp(-(x[:, 2] ** 2 + x[:, 3] ** 2))
    poly = 1.0 + 0.5 * x[:, 4] * x[:, 5]
    return t0 * t1 * gauss * poly


# ---------------------------------------------------------------------------
# fB: careful with the paper's formula. Eq. 8 reads
#   (1/sqrt(2 pi .01)^9) exp(-1/(2 (.01)^2) sum x_i^2)
# but the stated true value 1.0 over (-1,1)^9 corresponds to a Gaussian
# with variance .01 (sigma=0.1): norm (2 pi .01)^{-9/2}, exponent
# -sum x^2 / (2 * .01). We implement the *self-consistent* version that
# integrates to 1.0 (matching the paper's reported true value).
# ---------------------------------------------------------------------------


def fB_consistent(x, tables=None):
    var = 0.01  # sigma^2
    norm = (2.0 * math.pi * var) ** (-4.5)
    return norm * jnp.exp(-jnp.sum(x ** 2, axis=-1) / (2.0 * var))


# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class IntegrandSpec:
    name: str
    fn: Callable
    default_dim: Optional[int]
    lo: float
    hi: float
    n_tables: int = 0
    table_knots: int = 0
    symmetric: bool = False  # identical marginal density on every axis


REGISTRY: dict[str, IntegrandSpec] = {
    "f1": IntegrandSpec("f1", f1, None, 0.0, 1.0),
    "f2": IntegrandSpec("f2", f2, None, 0.0, 1.0, symmetric=True),
    "f3": IntegrandSpec("f3", f3, None, 0.0, 1.0),
    "f4": IntegrandSpec("f4", f4, None, 0.0, 1.0, symmetric=True),
    "f5": IntegrandSpec("f5", f5, None, 0.0, 1.0, symmetric=True),
    "f6": IntegrandSpec("f6", f6, None, 0.0, 1.0),
    "fA": IntegrandSpec("fA", fA, 6, 0.0, 10.0),
    "fB": IntegrandSpec("fB", fB_consistent, 9, -1.0, 1.0, symmetric=True),
    "cosmo": IntegrandSpec("cosmo", cosmo, 6, 0.0, 1.0, n_tables=2, table_knots=64),
}


def get(name: str) -> IntegrandSpec:
    try:
        return REGISTRY[name]
    except KeyError:
        raise KeyError(f"unknown integrand {name!r}; known: {sorted(REGISTRY)}")


def make_tables(spec: IntegrandSpec):
    """Deterministic runtime tables for stateful integrands (cosmo)."""
    if spec.n_tables == 0:
        return None
    k = spec.table_knots
    knots = jnp.linspace(0.0, 1.0, k)
    # Smooth but non-trivial profiles; deterministic so the Rust twin and
    # the true-value quadrature agree.
    t0 = 1.0 + 0.5 * jnp.sin(2.0 * math.pi * knots) + 0.25 * knots ** 2
    t1 = jnp.exp(-2.0 * (knots - 0.3) ** 2) + 0.1
    return jnp.stack([t0, t1])


# ---------------------------------------------------------------------------
# True values (analytic where available) for the accuracy experiments.
# ---------------------------------------------------------------------------


def true_value(name: str, d: int) -> float:
    if name == "f1":
        # prod rule via telescoping: Re[prod_j (e^{i j} - 1)/(i j)]
        re, im = 1.0, 0.0
        for j in range(1, d + 1):
            # integral of e^{i j x} over [0,1] = (sin j)/j + i(1-cos j)/j
            a = math.sin(j) / j
            b = (1.0 - math.cos(j)) / j
            re, im = re * a - im * b, re * b + im * a
        return re
    if name == "f2":
        one_dim = 50.0 * 2.0 * math.atan(25.0)
        return one_dim ** d
    if name == "f3":
        # Corner peak closed form (inclusion-exclusion):
        # I = (1/(d! prod c_i)) sum_{S subset [d]} (-1)^{|S|} / (1 + sum_{i in S} c_i)
        c = list(range(1, d + 1))
        total = 0.0
        for r in range(d + 1):
            for s in combinations(c, r):
                total += (-1.0) ** r / (1.0 + sum(s))
        return total / (math.factorial(d) * math.prod(c))
    if name == "f4":
        one_dim = math.sqrt(math.pi) / 25.0 * math.erf(12.5)
        return one_dim ** d
    if name == "f5":
        one_dim = 0.2 * (1.0 - math.exp(-5.0))
        return one_dim ** d
    if name == "f6":
        val = 1.0
        for i in range(1, d + 1):
            c = i + 4.0
            b = (3.0 + i) / 10.0
            val *= (math.exp(c * min(b, 1.0)) - 1.0) / c
        return val
    if name == "fA":
        # int sin(sum x) over (0,10)^6 = Im[ prod (e^{i 10}-1)/i ] = paper: -49.165073
        # 1-D: int_0^10 e^{i x} dx = sin(10) + i (1 - cos(10))
        a = math.sin(10.0)
        b = 1.0 - math.cos(10.0)
        re, im = 1.0, 0.0
        for _ in range(6):
            re, im = re * a - im * b, re * b + im * a
        return im  # Im of prod gives integral of sin(sum)
    if name == "fB":
        one_dim = math.erf(1.0 / (0.1 * math.sqrt(2.0)))
        return one_dim ** 9
    if name == "cosmo":
        return cosmo_true_value()
    raise KeyError(name)


def cosmo_true_value(n: int = 200001) -> float:
    """High-resolution product quadrature for the cosmo integrand."""
    import numpy as np

    spec = get("cosmo")
    tables = np.asarray(make_tables(spec))
    xs = np.linspace(0.0, 1.0, n)
    k = spec.table_knots
    t = np.clip(xs * (k - 1), 0.0, k - 1.000001)
    i0 = np.floor(t).astype(int)
    frac = t - i0
    i0_t0 = np.trapezoid(tables[0][i0] * (1 - frac) + tables[0][i0 + 1] * frac, xs)
    i0_t1 = np.trapezoid(tables[1][i0] * (1 - frac) + tables[1][i0 + 1] * frac, xs)
    gauss1d = np.trapezoid(np.exp(-(xs ** 2)), xs)
    # int (1 + .5 x4 x5) = 1 + .5 * .5 * .5 = 1.125
    return float(i0_t0 * i0_t1 * gauss1d ** 2 * 1.125)
