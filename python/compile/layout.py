"""Stratification layout — mirrors `rust/src/strat/` exactly.

Given `maxcalls` and dimension `d`, VEGAS (Algorithm 2) derives:
  g   intervals per axis        g = max(1, floor((maxcalls/2)^(1/d)))
  m   sub-cubes                 m = g^d
  p   samples per cube          p = max(2, floor(maxcalls / m))
  s   cube batch per "thread"   (Set-Batch-Size heuristic)

The Pallas kernel maps the paper's thread-groups onto grid programs:
`nblocks` programs, each owning `cpb = ceil(m / nblocks)` cubes,
vectorized internally. The Rust strat module reproduces these numbers so
the native engine and the AOT artifact sample identically.
"""

from __future__ import annotations

from dataclasses import dataclass


@dataclass(frozen=True)
class Layout:
    d: int
    nb: int          # bins per axis
    g: int           # intervals per axis
    m: int           # number of sub-cubes
    p: int           # samples per cube
    nblocks: int     # grid programs (paper: thread groups)
    cpb: int         # cubes per block (padded; last block masks)
    calls: int       # m * p, actual evaluations per iteration

    @property
    def samples_per_block(self) -> int:
        return self.cpb * self.p


def compute_layout(d: int, maxcalls: int, nb: int = 50, nblocks: int = 8) -> Layout:
    if d < 1:
        raise ValueError(f"dimension must be >= 1, got {d}")
    if maxcalls < 4:
        raise ValueError(f"maxcalls must be >= 4, got {maxcalls}")
    g = max(1, int((maxcalls / 2.0) ** (1.0 / d)))
    # Guard fp rounding: (g+1)^d might still be <= maxcalls/2.
    while (g + 1) ** d <= maxcalls // 2:
        g += 1
    m = g ** d
    p = max(2, maxcalls // m)
    nblocks = max(1, min(nblocks, m))
    cpb = (m + nblocks - 1) // nblocks
    # Shrink away fully-empty trailing blocks (cpb rounding can leave
    # grid programs with zero cubes). Mirrors rust strat::Layout.
    nblocks = (m + cpb - 1) // cpb
    return Layout(d=d, nb=nb, g=g, m=m, p=p, nblocks=nblocks, cpb=cpb, calls=m * p)


def batch_size_heuristic(maxcalls: int) -> int:
    """Paper's Set-Batch-Size: cubes each thread processes serially.

    Used by the Rust native engine for work partitioning; reproduced here
    so the manifest can carry it to the coordinator.
    """
    if maxcalls <= (1 << 15):
        return 1
    if maxcalls <= (1 << 20):
        return 2
    if maxcalls <= (1 << 25):
        return 4
    return 8
