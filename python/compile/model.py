"""L2: the jitted V-Sample computation — Pallas kernel + reduction epilogue.

One `build()` per (integrand, dim, maxcalls, variant) produces the jax
function that `aot.py` lowers to an HLO-text artifact. The function's
runtime signature (what the Rust coordinator feeds through PJRT):

  inputs : bins   f64[d, nb]   importance-bin right edges, unit space
           lo     f64[d]       integration box lower corner
           hi     f64[d]       integration box upper corner
           seedit u32[2]       (seed, iteration)
           tables f64[T, K]    only for stateful integrands
  outputs: res    f64[2]       (I, Var) for this iteration
           C      f64[d, nb]   bin contributions (adjust variant only)

Everything else (weighted combination across iterations, chi^2,
convergence, bin-boundary adjustment) lives in the Rust coordinator,
mirroring the paper's CPU/GPU split.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Callable, Optional

import jax
import jax.numpy as jnp

from . import integrands
from .layout import Layout, compute_layout
from .kernels.vsample import build_vsample_kernel


@dataclass(frozen=True)
class ModelSpec:
    """Everything needed to build + describe one artifact."""
    integrand: str
    dim: int
    maxcalls: int
    nb: int = 50
    nblocks: int = 8
    adjust: bool = True
    hist_mode: str = "scatter"

    @property
    def name(self) -> str:
        suffix = "adj" if self.adjust else "na"
        if self.adjust and self.hist_mode != "scatter":
            suffix += f"_{self.hist_mode}"
        return f"{self.integrand}_d{self.dim}_c{self.maxcalls}_{suffix}"

    def layout(self) -> Layout:
        return compute_layout(self.dim, self.maxcalls, self.nb, self.nblocks)


def build(spec: ModelSpec) -> tuple[Callable, Layout, Optional[tuple]]:
    """Return (fn, layout, table_shape). `fn` is ready for jax.jit."""
    ispec = integrands.get(spec.integrand)
    if ispec.default_dim is not None and spec.dim != ispec.default_dim:
        raise ValueError(
            f"{spec.integrand} is fixed at d={ispec.default_dim}, got {spec.dim}")
    layout = spec.layout()
    table_shape = ((ispec.n_tables, ispec.table_knots)
                   if ispec.n_tables else None)
    kernel = build_vsample_kernel(layout, ispec.fn, table_shape,
                                  adjust=spec.adjust, hist_mode=spec.hist_mode)

    if spec.adjust:
        def fn(bins, lo, hi, seed_it, *tables):
            res, c = kernel(bins, lo, hi, seed_it,
                            tables[0] if tables else None)
            return jnp.sum(res, axis=0), jnp.sum(c, axis=0)
    else:
        def fn(bins, lo, hi, seed_it, *tables):
            (res,) = kernel(bins, lo, hi, seed_it,
                            tables[0] if tables else None)
            return (jnp.sum(res, axis=0),)

    return fn, layout, table_shape


def example_args(spec: ModelSpec):
    """ShapeDtypeStructs for jit.lower()."""
    layout = spec.layout()
    args = [
        jax.ShapeDtypeStruct((layout.d, layout.nb), jnp.float64),  # bins
        jax.ShapeDtypeStruct((layout.d,), jnp.float64),            # lo
        jax.ShapeDtypeStruct((layout.d,), jnp.float64),            # hi
        jax.ShapeDtypeStruct((2,), jnp.uint32),                    # seed_it
    ]
    ispec = integrands.get(spec.integrand)
    if ispec.n_tables:
        args.append(jax.ShapeDtypeStruct((ispec.n_tables, ispec.table_knots),
                                         jnp.float64))
    return args
