"""Pure-jnp V-Sample oracle — the correctness reference for the kernel.

Evaluates *all* m*p samples of one VEGAS iteration in a single vectorized
pass, with exactly the same Philox stream, cube decode, and change of
variables as the Pallas kernel. The kernel must agree with this oracle to
fp-summation-order tolerance; the Rust native engine is cross-checked
against golden outputs generated from this module.
"""

from __future__ import annotations

import jax.numpy as jnp

from .. import sampling
from ..layout import Layout


def vsample_ref(fn, tables, bins, lo, hi, seed, iteration, layout: Layout,
                adjust: bool = True):
    """One full V-Sample pass over every sub-cube.

    Returns (I, Var, C) — integral estimate, variance of the estimate,
    and (d, nb) bin contributions (zeros when adjust=False).
    """
    d, nb, g, m, p = layout.d, layout.nb, layout.g, layout.m, layout.p
    cube = jnp.repeat(jnp.arange(m, dtype=jnp.int64), p)
    k = jnp.tile(jnp.arange(p, dtype=jnp.int64), m)
    u = sampling.draw_uniforms(cube, k, p, iteration, seed, d)
    coords = sampling.cube_coords(cube, g, d)
    x, jac, b = sampling.transform(u, coords, bins, lo, hi, nb, g)
    fv = fn(x, tables)
    v = fv * jac
    i_est, var_est = sampling.reduce_cubes(v, p, m)
    if adjust:
        c = sampling.bin_histogram(v, b, d, nb)
    else:
        c = jnp.zeros((d, nb), dtype=jnp.float64)
    return i_est, var_est, c


def uniform_bins(d: int, nb: int) -> jnp.ndarray:
    """Initial importance grid: equal-width bins, right edges only."""
    return jnp.tile(jnp.arange(1, nb + 1, dtype=jnp.float64) / nb, (d, 1))
