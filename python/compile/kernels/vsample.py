"""L1: the m-Cubes V-Sample Pallas kernel (and its No-Adjust twin).

Mapping of the paper's CUDA kernel (Algorithm 3) onto Pallas — see
DESIGN.md §Hardware-Adaptation:

  CUDA thread-block          -> grid program (nblocks of them)
  thread x serial cube batch -> one vectorized (cpb*p, d) sample batch
  shared-mem group reduction -> jnp.sum inside the program
  atomicAdd bin histogram    -> segment-sum scatter (CPU/interpret) or
                                one-hot MXU contraction (TPU plan)
  global atomic accumulation -> per-block partial outputs, reduced by a
                                tiny L2 epilogue (model.py)

The kernel is lowered with interpret=True: the CPU PJRT plugin cannot run
Mosaic custom-calls, and interpret-mode lowers to plain HLO that any
backend executes. Real-TPU performance is *estimated* structurally
(EXPERIMENTS.md §Perf) — interpret wallclock is not a TPU proxy.
"""

from __future__ import annotations

import functools
from typing import Callable, Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .. import sampling
from ..layout import Layout


def _kernel_body(layout: Layout, fn: Callable, has_tables: bool,
                 adjust: bool, hist_mode: str, *refs):
    """Shared body for the adjust / no-adjust kernel variants."""
    if has_tables:
        if adjust:
            bins_ref, lo_ref, hi_ref, seedit_ref, tab_ref, res_ref, c_ref = refs
        else:
            bins_ref, lo_ref, hi_ref, seedit_ref, tab_ref, res_ref = refs
        tables = tab_ref[...]
    else:
        if adjust:
            bins_ref, lo_ref, hi_ref, seedit_ref, res_ref, c_ref = refs
        else:
            bins_ref, lo_ref, hi_ref, seedit_ref, res_ref = refs
        tables = None

    d, nb, g, m, p = layout.d, layout.nb, layout.g, layout.m, layout.p
    cpb = layout.cpb

    bins = bins_ref[...].reshape(d, nb)
    lo = lo_ref[...].reshape(d)
    hi = hi_ref[...].reshape(d)
    seed = seedit_ref[0]
    iteration = seedit_ref[1]

    blk = pl.program_id(0)
    cube0 = blk.astype(jnp.int64) * cpb

    # The block's sample batch: cpb cubes x p samples, fully vectorized.
    cube_local = jnp.repeat(jnp.arange(cpb, dtype=jnp.int64), p)
    k = jnp.tile(jnp.arange(p, dtype=jnp.int64), cpb)
    cube = cube0 + cube_local
    valid = cube < m  # last block may own padding cubes

    u = sampling.draw_uniforms(cube, k, p, iteration, seed, d)
    coords = sampling.cube_coords(cube, g, d)
    x, jac, b = sampling.transform(u, coords, bins, lo, hi, nb, g)
    fv = fn(x, tables)
    v = jnp.where(valid, fv * jac, 0.0)

    i_partial, var_partial = sampling.reduce_cubes(v, p, m)
    res_ref[0, 0] = i_partial
    res_ref[0, 1] = var_partial

    if adjust:
        if hist_mode == "onehot":
            c = sampling.bin_histogram_onehot(v, b, d, nb)
        else:
            c = sampling.bin_histogram(v, b, d, nb)
        c_ref[0, :, :] = c


def build_vsample_kernel(layout: Layout, fn: Callable,
                         table_shape: Optional[tuple] = None,
                         adjust: bool = True,
                         hist_mode: str = "scatter") -> Callable:
    """Build the pallas_call for one (integrand, layout, variant) triple.

    Returns a function (bins, lo, hi, seed_it[, tables]) ->
      (res[nblocks, 2], C[nblocks, d, nb])   when adjust
      (res[nblocks, 2],)                     otherwise
    Partial outputs are per-block; the L2 model sums them (the paper's
    final global atomicAdd, done as a reduction epilogue).
    """
    d, nb = layout.d, layout.nb
    nblocks = layout.nblocks
    has_tables = table_shape is not None

    body = functools.partial(_kernel_body, layout, fn, has_tables,
                             adjust, hist_mode)

    in_specs = [
        pl.BlockSpec((d, nb), lambda i: (0, 0)),      # bins
        pl.BlockSpec((d,), lambda i: (0,)),           # lo
        pl.BlockSpec((d,), lambda i: (0,)),           # hi
        pl.BlockSpec((2,), lambda i: (0,)),           # seed, iteration
    ]
    if has_tables:
        in_specs.append(pl.BlockSpec(table_shape, lambda i: (0,) * len(table_shape)))

    out_shape = [jax.ShapeDtypeStruct((nblocks, 2), jnp.float64)]
    out_specs = [pl.BlockSpec((1, 2), lambda i: (i, 0))]
    if adjust:
        out_shape.append(jax.ShapeDtypeStruct((nblocks, d, nb), jnp.float64))
        out_specs.append(pl.BlockSpec((1, d, nb), lambda i: (i, 0, 0)))

    call = pl.pallas_call(
        body,
        grid=(nblocks,),
        in_specs=in_specs,
        out_specs=out_specs,
        out_shape=out_shape,
        interpret=True,
    )

    def vsample(bins, lo, hi, seed_it, tables=None):
        args = [bins, lo, hi, seed_it]
        if has_tables:
            assert tables is not None, "stateful integrand needs tables"
            args.append(tables)
        return call(*args)

    return vsample
