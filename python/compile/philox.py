"""Philox4x32-10 counter-based RNG, vectorized in jnp.

This is the same generator family curand uses (the paper's CUDA kernels
draw from curand); implementing it identically here, in the Pallas kernel,
in the pure-jnp oracle, and in Rust (`rust/src/rng/philox.rs`) means every
backend draws the *same* sample sequence for a given (seed, iteration) —
the foundation of the cross-layer equivalence tests.

Conventions (Random123): 10 rounds, round-then-bump key schedule.
"""

from __future__ import annotations

import jax.numpy as jnp

# Multiplication constants (Random123 / curand). Plain Python ints so
# they stay jaxpr literals (Pallas kernels may not close over arrays).
PHILOX_M0 = 0xD2511F53
PHILOX_M1 = 0xCD9E8D57
# Weyl key increments.
PHILOX_W0 = 0x9E3779B9
PHILOX_W1 = 0xBB67AE85

# Domain-separation constant baked into counter word 3 ("mCUB").
CTR_MAGIC = 0x6D435542
# Key word 1 constant ("mcub").
KEY_MAGIC = 0x6D637562


def _mulhilo(a: jnp.ndarray, b) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Full 32x32 -> 64 bit product, split into (hi, lo) 32-bit words."""
    prod = a.astype(jnp.uint64) * jnp.uint64(b)
    hi = (prod >> jnp.uint64(32)).astype(jnp.uint32)
    lo = prod.astype(jnp.uint32)
    return hi, lo


def philox4x32(c0, c1, c2, c3, k0, k1):
    """Philox4x32-10 on vectorized uint32 counter/key words.

    All inputs broadcast together; returns four uint32 arrays of the
    broadcast shape.
    """
    c0 = jnp.asarray(c0, jnp.uint32)
    c1 = jnp.asarray(c1, jnp.uint32)
    c2 = jnp.asarray(c2, jnp.uint32)
    c3 = jnp.asarray(c3, jnp.uint32)
    k0 = jnp.asarray(k0, jnp.uint32)
    k1 = jnp.asarray(k1, jnp.uint32)
    for _ in range(10):
        hi0, lo0 = _mulhilo(c0, PHILOX_M0)
        hi1, lo1 = _mulhilo(c2, PHILOX_M1)
        # One Philox round (Random123 ordering).
        c0, c1, c2, c3 = hi1 ^ c1 ^ k0, lo1, hi0 ^ c3 ^ k1, lo0
        k0 = (k0 + jnp.uint32(PHILOX_W0)).astype(jnp.uint32)
        k1 = (k1 + jnp.uint32(PHILOX_W1)).astype(jnp.uint32)
    return c0, c1, c2, c3


def u32_to_unit_f64(u: jnp.ndarray) -> jnp.ndarray:
    """Map uint32 -> double in the open interval (0, 1)."""
    return (u.astype(jnp.float64) + 0.5) * (2.0 ** -32)


def uniforms(sample_idx: jnp.ndarray, iteration, seed, ndim: int) -> jnp.ndarray:
    """Draw `ndim` doubles in (0,1) for each entry of `sample_idx`.

    sample_idx : uint32 array (N,) — globally unique sample number
                 (cube_index * samples_per_cube + sample_in_cube).
    iteration  : scalar uint32 — VEGAS iteration number (domain separation
                 so every iteration resamples).
    seed       : scalar uint32 — user seed (key word 0).

    Counter layout: (sample_idx, draw_block, iteration, CTR_MAGIC);
    key: (seed, KEY_MAGIC). Each Philox call yields 4 words, so
    ceil(ndim/4) calls per sample.
    """
    sample_idx = jnp.asarray(sample_idx, jnp.uint32)
    iteration = jnp.asarray(iteration, jnp.uint32)
    seed = jnp.asarray(seed, jnp.uint32)
    nblocks = (ndim + 3) // 4
    cols = []
    for j in range(nblocks):
        r0, r1, r2, r3 = philox4x32(
            sample_idx,
            jnp.uint32(j),
            iteration,
            jnp.uint32(CTR_MAGIC),
            seed,
            jnp.uint32(KEY_MAGIC),
        )
        cols.extend([r0, r1, r2, r3])
    u = jnp.stack(cols[:ndim], axis=-1)  # (N, ndim) uint32
    return u32_to_unit_f64(u)
