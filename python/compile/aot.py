"""AOT lowering: jit -> StableHLO -> XLA computation -> HLO *text* artifacts.

HLO text (not `.serialize()`) is the interchange format: jax >= 0.5 emits
HloModuleProto with 64-bit instruction ids which xla_extension 0.5.1 (the
version the published `xla` 0.1.6 crate binds) rejects; the text parser
reassigns ids and round-trips cleanly. See /opt/xla-example/README.md.

Outputs (under --out, default ../artifacts):
  <name>.hlo.txt          one per (integrand, dim, maxcalls, variant)
  manifest.json           registry the Rust runtime loads
  tables.json             runtime interpolation tables for stateful integrands
  golden_philox.json      Philox KAT + stream vectors for the Rust RNG test
  golden_vsample.json     oracle outputs for Rust<->PJRT cross-checks

Usage: python -m compile.aot [--out DIR] [--set test|bench|all] [--force]
"""

from __future__ import annotations

import argparse
import json
import os
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import integrands, philox
from .kernels import ref
from .layout import batch_size_heuristic
from .model import ModelSpec, build, example_args

# ---------------------------------------------------------------------------
# Artifact sets. (integrand, dim) pairs follow the paper's evaluation:
# f2@6, f3@{3,8}, f4@{5,8}, f5@8, f6@6 (Fig 1-3), fA/fB (Table 1-2),
# cosmo (section 6.1 stateful integrand).
# ---------------------------------------------------------------------------

PAPER_CASES: list[tuple[str, int]] = [
    ("f1", 5),
    ("f2", 6),
    ("f3", 3),
    ("f3", 8),
    ("f4", 5),
    ("f4", 8),
    ("f5", 8),
    ("f6", 6),
    ("fA", 6),
    ("fB", 9),
    ("cosmo", 6),
]

TEST_CALLS = [1 << 14]
BENCH_CALLS = [1 << 17, 1 << 20]


def specs_for(set_name: str) -> list[ModelSpec]:
    if set_name == "test":
        calls = TEST_CALLS
    elif set_name == "bench":
        calls = BENCH_CALLS
    elif set_name == "all":
        calls = TEST_CALLS + BENCH_CALLS
    else:
        raise ValueError(f"unknown set {set_name!r}")
    out = []
    for name, dim in PAPER_CASES:
        for c in calls:
            for adjust in (True, False):
                out.append(ModelSpec(name, dim, c, adjust=adjust))
    # Ablation artifact: one-hot (MXU-shaped) histogram variant.
    out.append(ModelSpec("f4", 5, TEST_CALLS[0], adjust=True,
                         hist_mode="onehot"))
    return out


# ---------------------------------------------------------------------------
# Lowering
# ---------------------------------------------------------------------------


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_spec(spec: ModelSpec) -> tuple[str, dict]:
    fn, layout, table_shape = build(spec)
    args = example_args(spec)
    lowered = jax.jit(fn).lower(*args)
    text = to_hlo_text(lowered)
    ispec = integrands.get(spec.integrand)
    outputs = [{"name": "res", "shape": [2], "dtype": "f64"}]
    if spec.adjust:
        outputs.append({"name": "bin_contrib", "shape": [layout.d, layout.nb],
                        "dtype": "f64"})
    inputs = [
        {"name": "bins", "shape": [layout.d, layout.nb], "dtype": "f64"},
        {"name": "lo", "shape": [layout.d], "dtype": "f64"},
        {"name": "hi", "shape": [layout.d], "dtype": "f64"},
        {"name": "seed_it", "shape": [2], "dtype": "u32"},
    ]
    if table_shape is not None:
        inputs.append({"name": "tables", "shape": list(table_shape),
                       "dtype": "f64"})
    entry = {
        "name": spec.name,
        "file": f"{spec.name}.hlo.txt",
        "integrand": spec.integrand,
        "dim": layout.d,
        "nb": layout.nb,
        "g": layout.g,
        "m": layout.m,
        "p": layout.p,
        "nblocks": layout.nblocks,
        "cpb": layout.cpb,
        "maxcalls": spec.maxcalls,
        "calls": layout.calls,
        "adjust": spec.adjust,
        "hist_mode": spec.hist_mode,
        "batch_size": batch_size_heuristic(spec.maxcalls),
        "lo": ispec.lo,
        "hi": ispec.hi,
        "symmetric": ispec.symmetric,
        "n_tables": ispec.n_tables,
        "table_knots": ispec.table_knots,
        "true_value": integrands.true_value(spec.integrand, layout.d),
        "inputs": inputs,
        "outputs": outputs,
    }
    return text, entry


# ---------------------------------------------------------------------------
# Goldens
# ---------------------------------------------------------------------------


def skewed_bins(d: int, nb: int, gamma: float = 1.7) -> np.ndarray:
    """Deterministic non-uniform bin edges: exercises the gather path."""
    edges = ((np.arange(1, nb + 1) / nb) ** gamma)
    edges[-1] = 1.0
    return np.tile(edges, (d, 1))


def golden_philox() -> dict:
    cases = []
    for (c, k) in [((0, 0, 0, 0), (0, 0)),
                   ((0xFFFFFFFF,) * 4, (0xFFFFFFFF,) * 2),
                   ((0x243F6A88, 0x85A308D3, 0x13198A2E, 0x03707344),
                    (0xA4093822, 0x299F31D0)),
                   ((1, 2, 3, 4), (5, 6))]:
        r = philox.philox4x32(*c, *k)
        cases.append({"ctr": list(c), "key": list(k),
                      "out": [int(x) for x in r]})
    # A uniform stream segment as drawn by the sampler.
    u = philox.uniforms(jnp.arange(16, dtype=jnp.uint32), 3, 42, 6)
    return {
        "kat": cases,
        "uniforms": {
            "iteration": 3, "seed": 42, "ndim": 6, "n": 16,
            "values": np.asarray(u).reshape(-1).tolist(),
        },
    }


def golden_vsample() -> list[dict]:
    out = []
    for name, dim, calls, bins_kind, seed, it in [
        ("f4", 5, 1 << 14, "uniform", 123, 0),
        ("f4", 5, 1 << 14, "skewed", 123, 3),
        ("f2", 6, 1 << 14, "uniform", 7, 1),
        ("fB", 9, 1 << 14, "skewed", 99, 2),
        ("cosmo", 6, 1 << 14, "uniform", 5, 0),
    ]:
        spec = ModelSpec(name, dim, calls)
        layout = spec.layout()
        ispec = integrands.get(name)
        tables = integrands.make_tables(ispec)
        if bins_kind == "uniform":
            bins = np.asarray(ref.uniform_bins(dim, layout.nb))
        else:
            bins = skewed_bins(dim, layout.nb)
        lo = jnp.full(dim, ispec.lo)
        hi = jnp.full(dim, ispec.hi)
        i_est, var_est, c = ref.vsample_ref(
            ispec.fn, tables, jnp.asarray(bins), lo, hi, seed, it, layout)
        c = np.asarray(c)
        out.append({
            "artifact": spec.name,
            "bins": bins_kind,
            "seed": seed,
            "iteration": it,
            "integral": float(i_est),
            "variance": float(var_est),
            "c_axis_sums": c.sum(axis=1).tolist(),
            "c_full": c.tolist() if name == "f4" else None,
        })
    return out


# ---------------------------------------------------------------------------
# Main
# ---------------------------------------------------------------------------


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--set", default="test", choices=["test", "bench", "all"])
    ap.add_argument("--force", action="store_true")
    args = ap.parse_args()

    os.makedirs(args.out, exist_ok=True)
    manifest_path = os.path.join(args.out, "manifest.json")
    existing: dict[str, dict] = {}
    if os.path.exists(manifest_path) and not args.force:
        with open(manifest_path) as f:
            existing = {e["name"]: e for e in json.load(f)["artifacts"]}

    entries = dict(existing)
    t_all = time.time()
    for spec in specs_for(args.set):
        path = os.path.join(args.out, f"{spec.name}.hlo.txt")
        if spec.name in entries and os.path.exists(path) and not args.force:
            print(f"  [skip] {spec.name}")
            continue
        t0 = time.time()
        text, entry = lower_spec(spec)
        with open(path, "w") as f:
            f.write(text)
        entries[spec.name] = entry
        print(f"  [ok]   {spec.name}  ({len(text)/1024:.0f} KiB, "
              f"{time.time()-t0:.1f}s)")

    with open(manifest_path, "w") as f:
        json.dump({"version": 1, "artifacts": list(entries.values())}, f,
                  indent=1)

    # Runtime tables for stateful integrands.
    cosmo = integrands.get("cosmo")
    tables = np.asarray(integrands.make_tables(cosmo))
    with open(os.path.join(args.out, "tables.json"), "w") as f:
        json.dump({"cosmo": {"knots": cosmo.table_knots,
                             "values": tables.tolist()}}, f)

    with open(os.path.join(args.out, "golden_philox.json"), "w") as f:
        json.dump(golden_philox(), f, indent=1)
    with open(os.path.join(args.out, "golden_vsample.json"), "w") as f:
        json.dump(golden_vsample(), f, indent=1)

    print(f"artifacts complete in {time.time()-t_all:.1f}s -> {args.out}")


if __name__ == "__main__":
    main()
