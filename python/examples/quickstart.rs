fn main() {}
