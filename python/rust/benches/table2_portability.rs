fn main() { println!("placeholder"); }
