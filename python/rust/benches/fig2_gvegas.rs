fn main() { println!("placeholder"); }
