fn main() { println!("placeholder"); }
