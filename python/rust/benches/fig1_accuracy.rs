fn main() { println!("placeholder"); }
