fn main() { println!("placeholder"); }
