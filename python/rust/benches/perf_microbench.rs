fn main() { println!("placeholder"); }
