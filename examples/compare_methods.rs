//! Compare every integrator in the library on one integrand: m-Cubes
//! (native), m-Cubes1D, serial VEGAS, gVegas-sim, ZMC-sim, MISER, and
//! plain MC — estimate, error, calls, and wall time side by side.
//!
//! Run: cargo run --offline --release --example compare_methods [integrand] [dim]

use mcubes::baselines::*;
use mcubes::prelude::*;
use mcubes::util::table::{fmt_ms, Table};

fn main() -> Result<()> {
    let name = std::env::args().nth(1).unwrap_or_else(|| "f4".into());
    let dim: usize = std::env::args()
        .nth(2)
        .and_then(|s| s.parse().ok())
        .unwrap_or(5);
    let f = mcubes::integrands::by_name(&name, dim)?;
    let truth = f.true_value();
    let calls = 1 << 16;
    let tau = 1e-3;
    let seed = 31;

    let mut t = Table::new(&[
        "method", "estimate", "errorest", "rel-true", "calls", "time",
    ]);
    let mut push = |label: &str, i: f64, s: f64, calls: usize, secs: f64| {
        let rel = truth
            .map(|tv| format!("{:.2e}", ((i - tv) / tv).abs()))
            .unwrap_or_else(|| "-".into());
        t.row(vec![
            label.into(),
            format!("{i:.8e}"),
            format!("{s:.2e}"),
            rel,
            calls.to_string(),
            fmt_ms(secs * 1e3),
        ]);
    };

    let base = || {
        Integrator::new(f.clone())
            .maxcalls(calls)
            .tolerance(tau)
            .plan(RunPlan::classic(20, 12, 2))
            .seed(seed)
    };
    let mc = base().run()?;
    push("m-Cubes", mc.integral, mc.sigma, mc.calls_used, mc.total_time);

    if f.symmetric() {
        let m1 = base().grid_mode(GridMode::Shared1D).run()?;
        push(
            "m-Cubes1D",
            m1.integral,
            m1.sigma,
            m1.calls_used,
            m1.total_time,
        );
    }

    let vs = vegas_serial_integrate(&f, calls, tau, 20, seed);
    push(
        "serial VEGAS",
        vs.integral,
        vs.sigma,
        vs.calls_used,
        vs.total_time,
    );

    let gv = gvegas_integrate(
        &*f,
        &GvegasConfig {
            maxcalls: calls,
            tau_rel: tau,
            itmax: 20,
            seed,
            ..Default::default()
        },
    );
    push(
        "gVegas-sim",
        gv.integral,
        gv.sigma,
        gv.calls_used,
        gv.total_time,
    );

    let zm = zmc_integrate(
        &*f,
        &ZmcConfig {
            samples_per_block: 256,
            depth: 4,
            seed,
            ..Default::default()
        },
    );
    push("ZMC-sim", zm.integral, zm.sigma, zm.calls_used, zm.total_time);

    let mi = miser_integrate(
        &*f,
        &MiserConfig {
            calls: calls * 4,
            seed,
            ..Default::default()
        },
    );
    push("MISER", mi.integral, mi.sigma, mi.calls_used, mi.total_time);

    let pm = plain_mc_integrate(
        &*f,
        &PlainMcConfig {
            calls: calls * 4,
            seed,
        },
    );
    push("plain MC", pm.integral, pm.sigma, pm.calls_used, pm.total_time);

    println!("integrand {name} (d={dim}), tau_rel {tau:.0e}");
    if let Some(tv) = truth {
        println!("true value = {tv:.10e}");
    }
    println!("\n{}", t.render());
    Ok(())
}
