//! Uniform m-Cubes vs VEGAS+ adaptive stratification on a peaked Genz
//! integrand (the paper's f4, `exp(-625 Σ (x_i - 1/2)²)` — a sharp
//! Gaussian product peak that concentrates nearly all the variance in
//! the few sub-cubes around the box center).
//!
//! The observer hook prints the per-iteration allocation spread
//! (min/mean/max samples per cube): under `Sampling::Uniform` it never
//! moves; under `Sampling::VegasPlus` the budget visibly migrates into
//! the peak cubes while the total stays fixed.
//!
//! Run: cargo run --offline --release --example vegas_plus

use mcubes::prelude::*;

fn run(label: &str, sampling: Sampling) -> Result<IntegrationOutput> {
    println!("{label}:");
    let out = Integrator::from_registry("f4", 8)?
        .maxcalls(1 << 16) // g=3, m=6561, p=9: real re-allocation headroom
        .tolerance(5e-3)
        .plan(RunPlan::classic(30, 24, 2))
        .seed(2024)
        .sampling(sampling)
        .observe(|ev| match ev.alloc {
            Some(a) => println!(
                "  it {:>2}: rel {:.2e}  samples/cube min {:>2} mean {:>5.1} max {:>5}",
                ev.iteration, ev.rel_err, a.min, a.mean, a.max
            ),
            None => println!("  it {:>2}: rel {:.2e}  (uniform split)", ev.iteration, ev.rel_err),
        })
        .run()?;
    println!(
        "  => I = {:.6e} ± {:.1e}  ({} iterations, {} calls, converged: {})\n",
        out.integral, out.sigma, out.iterations, out.calls_used, out.converged
    );
    Ok(out)
}

fn main() -> Result<()> {
    println!("f4 (8-D sharp Gaussian peak), same budget and seed for both:\n");
    let uniform = run("uniform m-Cubes allocation", Sampling::Uniform)?;
    let vegas = run(
        "VEGAS+ adaptive stratification (beta = 0.75)",
        Sampling::vegas_plus(),
    )?;

    let truth = mcubes::integrands::by_name("f4", 8)?
        .true_value()
        .expect("f4 has an analytic value");
    println!("true value   = {truth:.6e}");
    println!(
        "uniform      : rel-true {:.2e}, {} calls",
        ((uniform.integral - truth) / truth).abs(),
        uniform.calls_used
    );
    println!(
        "vegas+       : rel-true {:.2e}, {} calls",
        ((vegas.integral - truth) / truth).abs(),
        vegas.calls_used
    );
    if vegas.calls_used < uniform.calls_used {
        println!(
            "vegas+ reached tau with {:.0}% fewer calls",
            (1.0 - vegas.calls_used as f64 / uniform.calls_used as f64) * 100.0
        );
    }
    Ok(())
}
