//! Sharded execution demo: one 2^33-call integral (past the 32-bit
//! sample-index boundary) split across 8 in-process shard workers,
//! then checked bitwise against the single-worker run.
//!
//! The shard plan partitions the iteration's reduction-task index
//! space into contiguous spans, each owning a disjoint Philox counter
//! sub-range — so the merged N-shard fold is the single-worker fold,
//! bit for bit (see docs/sharding.md). At 2^33 calls the layout holds
//! ~2^32 sub-cubes, so the demo uses the paper's uniform allocation
//! (VEGAS+ would need a per-cube table; sharded VEGAS+ equivalence is
//! pinned at saner sizes in rust/tests/shard_equivalence.rs).
//!
//! Run: cargo run --offline --release --example sharded_run
//!
//! The default 2^33 evaluations per pass take minutes on a laptop; set
//! MCUBES_SHARD_DEMO_CALLS to shrink the demo (CI uses 2^21):
//!
//!   MCUBES_SHARD_DEMO_CALLS=2097152 cargo run --release --example sharded_run

use mcubes::prelude::*;

fn run(calls: usize, shards: usize) -> Result<IntegrationOutput> {
    Integrator::from_registry("f4", 8)?
        .maxcalls(calls)
        .tolerance(1e-12) // never converges early: one full-budget pass
        .plan(RunPlan::classic(1, 0, 0))
        .seed(2026)
        .threads(8)
        .shards(shards)
        .run()
}

fn main() -> Result<()> {
    let calls = match std::env::var("MCUBES_SHARD_DEMO_CALLS") {
        Ok(v) => v
            .parse()
            .map_err(|_| Error::Config(format!("MCUBES_SHARD_DEMO_CALLS: bad value `{v}`")))?,
        Err(_) => 1usize << 33, // past the 2^32 sample-index boundary
    };
    let shards = 8;

    // The plan is a pure function of the layout — show the partition
    // before burning any cycles on it.
    let layout = Layout::compute(8, calls, 500, 1 << 12)?;
    let plan = ShardPlan::uniform(&layout, shards);
    println!(
        "layout: {} cubes x {} samples = {} calls/iteration ({} reduction tasks)",
        layout.m,
        layout.p,
        layout.calls(),
        plan.ntasks()
    );
    for sp in plan.spans() {
        println!(
            "  shard {}: tasks [{:>2}, {:>2})  cubes [{:>10}, {:>10})  counters [{:>10}, {:>10})",
            sp.shard, sp.task_lo, sp.task_hi, sp.cube_lo, sp.cube_hi, sp.counter_lo, sp.counter_hi
        );
    }

    println!("\n{shards}-shard run:");
    let sharded = run(calls, shards)?;
    println!(
        "  I = {:.6e} ± {:.1e}  ({} iterations, {} calls) via {}",
        sharded.integral, sharded.sigma, sharded.iterations, sharded.calls_used, sharded.backend
    );

    println!("single-worker reference:");
    let single = run(calls, 1)?;
    println!(
        "  I = {:.6e} ± {:.1e}  ({} iterations, {} calls) via {}",
        single.integral, single.sigma, single.iterations, single.calls_used, single.backend
    );

    assert_eq!(
        sharded.integral.to_bits(),
        single.integral.to_bits(),
        "sharded integral must be bitwise equal to the single worker"
    );
    assert_eq!(
        sharded.sigma.to_bits(),
        single.sigma.to_bits(),
        "sharded sigma must be bitwise equal to the single worker"
    );
    println!("\nbitwise check: {shards}-shard == single worker (integral and sigma)");
    Ok(())
}
