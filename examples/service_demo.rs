//! End-to-end system driver (the repo's E2E validation workload):
//!
//! 1. Loads the AOT artifact registry and checks the PJRT runtime.
//! 2. Cross-validates PJRT vs native on one artifact through the
//!    `Integrator` facade (the three-layer stack composes).
//! 3. Pushes a realistic batch of integration jobs (the paper's test
//!    suite at 3 digits of precision, many seeds) through the
//!    throughput scheduler — time-sliced round-robin sessions with a
//!    priority lane and a streamed result feed — including a closure
//!    integrand and a warm-started repeat batch — and reports
//!    latency/throughput plus per-integrand accuracy vs the analytic
//!    values.
//!
//! Results are recorded in EXPERIMENTS.md §E2E. Run:
//!   cargo run --offline --release --example service_demo

// Narrowing / float→int casts in this file are deliberate and
// audited by `cargo xtask lint` (MC001); see docs/invariants.md.
#![allow(clippy::cast_possible_truncation)]

use mcubes::coordinator::{JobRequest, Scheduler};
use mcubes::prelude::*;
use mcubes::runtime::{PjrtRuntime, Registry, DEFAULT_ARTIFACT_DIR};
use mcubes::util::table::{fmt_ms, Table};

fn main() -> Result<()> {
    // ---- Stage 1: artifact registry + PJRT sanity --------------------
    let registry = Registry::load(DEFAULT_ARTIFACT_DIR)
        .map_err(|e| Error::Runtime(format!("{e}\nhint: run `make artifacts` first")))?;
    println!(
        "[1/3] registry: {} artifacts from {}",
        registry.all().len(),
        registry.dir().display()
    );
    let runtime = PjrtRuntime::cpu()?;
    println!(
        "      pjrt: platform={} devices={}",
        runtime.platform_name(),
        runtime.device_count()
    );

    // ---- Stage 2: cross-backend validation through the facade --------
    // Same compiled layout on both sides: adopt the smallest f4
    // artifact's (maxcalls, nb, nblocks) for the native run too.
    let meta = registry.select("f4", true, 4)?.clone();
    let xcheck = |backend: BackendSpec| -> Result<IntegrationOutput> {
        Integrator::from_registry("f4", 5)?
            .backend(backend)
            .maxcalls(meta.maxcalls)
            .bins_per_axis(meta.nb)
            .blocks(meta.nblocks)
            .plan(RunPlan::classic(4, 3, 0))
            .tolerance(1e-14)
            .seed(999)
            .run()
    };
    let pjrt = xcheck(BackendSpec::Pjrt {
        artifacts_dir: DEFAULT_ARTIFACT_DIR.into(),
    })?;
    let native = xcheck(BackendSpec::Native)?;
    let rel = ((pjrt.integral - native.integral) / native.integral).abs();
    println!(
        "[2/3] cross-backend check on f4: pjrt={:.12e} native={:.12e} rel diff={:.2e}",
        pjrt.integral, native.integral, rel
    );
    assert!(rel < 1e-9, "backends disagree");

    // ---- Stage 3: batched service workload ----------------------------
    let suite: &[(&str, usize, usize)] = &[
        ("f2", 6, 1 << 15),
        ("f3", 3, 1 << 14),
        ("f3", 8, 1 << 16),
        ("f4", 5, 1 << 16),
        ("f5", 8, 1 << 15),
        ("f6", 6, 1 << 16),
        ("cosmo", 6, 1 << 14),
    ];
    let seeds_per_case = 4usize;
    let workers = std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(2)
        .clamp(1, 8);
    let mut svc = Scheduler::new(workers);
    // Fairness quantum: no job may hog a worker for more than ~4
    // default iterations before yielding to its priority peers.
    svc.calls_budget(1 << 18);
    let mut id = 0u64;
    for (name, d, calls) in suite {
        for s in 0..seeds_per_case {
            svc.submit(JobRequest::registry(
                id,
                *name,
                *d,
                JobConfig::default()
                    .with_maxcalls(*calls)
                    .with_tolerance(1e-3)
                    .with_plan(RunPlan::classic(20, 12, 2))
                    .with_seed(7000 + id as u32 + s as u32),
            ));
            id += 1;
        }
    }
    // A closure job rides along — no registry entry needed — on the
    // high-priority lane (it jumps the queued registry jobs).
    let closure_id = id;
    svc.submit(
        JobRequest::custom(
            closure_id,
            FnIntegrand::unit(4, |x: &[f64]| {
                (-(x.iter().map(|v| (v - 0.5) * (v - 0.5)).sum::<f64>()) * 20.0).exp()
            })
            .named("gauss4")
            .into_ref(),
            JobConfig::default()
                .with_maxcalls(1 << 14)
                .with_tolerance(1e-3)
                .with_plan(RunPlan::classic(20, 12, 2))
                .with_seed(4242),
        )
        .with_priority(10),
    );
    id += 1;
    println!(
        "[3/3] scheduler: {} jobs ({} integrand cases x {} seeds + 1 priority closure) \
         on {} workers, quantum 2^18 calls",
        id,
        suite.len(),
        seeds_per_case,
        workers
    );
    // Stream results as they complete (completion order, not id order).
    let mut completed = 0usize;
    let (results, metrics) = svc.drain_with(|r| {
        completed += 1;
        if completed % 8 == 0 {
            println!("      ... {completed} jobs done (latest: {} #{})", r.integrand, r.id);
        }
    })?;

    let mut t = Table::new(&[
        "integrand",
        "jobs",
        "converged",
        "max |rel err| vs truth",
        "median latency",
    ]);
    for (name, d, _) in suite {
        let f = mcubes::integrands::by_name(name, *d)?;
        let truth = f.true_value().unwrap();
        let mut rels: Vec<f64> = Vec::new();
        let mut lats: Vec<f64> = Vec::new();
        let mut conv = 0;
        let key = name.to_string();
        for r in results.iter().filter(|r| r.integrand == key && r.dim == *d) {
            if let Ok(o) = &r.outcome {
                if o.calls_used > 0 {
                    rels.push(((o.integral - truth) / truth).abs());
                    lats.push(r.latency);
                    conv += usize::from(o.converged);
                }
            }
        }
        lats.sort_by(f64::total_cmp);
        let max_rel = rels.iter().cloned().fold(0.0f64, f64::max);
        t.row(vec![
            format!("{name} (d={d})"),
            rels.len().to_string(),
            format!("{conv}/{}", rels.len()),
            format!("{max_rel:.2e}"),
            fmt_ms(lats.get(lats.len() / 2).copied().unwrap_or(0.0) * 1e3),
        ]);
    }
    println!("\n{}", t.render());
    let closure_result = results.iter().find(|r| r.id == closure_id).unwrap();
    println!(
        "closure job `{}`: {}",
        closure_result.integrand,
        match &closure_result.outcome {
            Ok(o) => format!("I = {:.6e} (converged: {})", o.integral, o.converged),
            Err(e) => format!("ERROR: {e}"),
        }
    );
    println!(
        "throughput: {:.2} jobs/s | {:.2e} calls/s | wall {} | p50 {} | p95 {} | failures {}",
        metrics.throughput,
        metrics.calls_per_sec,
        fmt_ms(metrics.wall_time * 1e3),
        fmt_ms(metrics.latency_p50 * 1e3),
        fmt_ms(metrics.latency_p95 * 1e3),
        metrics.failures
    );
    assert_eq!(metrics.failures, 0);

    // ---- Warm-started repeat batch: the grid-reuse serving win -------
    let donor_grid = results
        .iter()
        .find(|r| r.integrand == "f4" && r.outcome.is_ok())
        .and_then(|r| r.grid.clone())
        .expect("f4 grid");
    let mut svc = Scheduler::new(workers);
    for i in 0..4u64 {
        svc.submit(
            JobRequest::registry(
                i,
                "f4",
                5,
                JobConfig::default()
                    .with_maxcalls(1 << 16)
                    .with_tolerance(1e-3)
                    // grid already adapted: no adjust, no discard
                    .with_plan(RunPlan::classic(20, 0, 0))
                    .with_seed(9900 + i as u32),
            )
            .with_warm_start(donor_grid.clone()),
        );
    }
    let (warm_results, warm_metrics) = svc.drain()?;
    let mean_iters: f64 = warm_results
        .iter()
        .filter_map(|r| r.outcome.as_ref().ok())
        .map(|o| o.iterations as f64)
        .sum::<f64>()
        / warm_results.len() as f64;
    println!(
        "warm-started f4 batch: {} jobs, mean {:.1} iterations (cold runs take the full \
         adjust phase), p50 {}",
        warm_metrics.jobs,
        mean_iters,
        fmt_ms(warm_metrics.latency_p50 * 1e3)
    );
    assert_eq!(warm_metrics.failures, 0);

    println!(
        "\nservice_demo OK — full stack (artifacts -> PJRT -> coordinator -> scheduler) validated"
    );
    Ok(())
}
