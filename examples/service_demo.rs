//! End-to-end system driver (the repo's E2E validation workload):
//!
//! 1. Loads the AOT artifact registry and checks the PJRT runtime.
//! 2. Cross-validates PJRT vs native on one artifact (the three-layer
//!    stack composes).
//! 3. Pushes a realistic batch of integration jobs (the paper's test
//!    suite at 3 digits of precision, many seeds) through the
//!    integration service and reports latency/throughput plus
//!    per-integrand accuracy vs the analytic values.
//!
//! Results are recorded in EXPERIMENTS.md §E2E. Run:
//!   cargo run --offline --release --example service_demo

use mcubes::coordinator::{
    run_driver, IntegrationService, JobConfig, JobRequest, PjrtBackend,
};
use mcubes::integrands::by_name;
use mcubes::runtime::{PjrtRuntime, Registry, DEFAULT_ARTIFACT_DIR};
use mcubes::util::table::{fmt_ms, Table};

fn main() -> anyhow::Result<()> {
    // ---- Stage 1: artifact registry + PJRT sanity --------------------
    let registry = Registry::load(DEFAULT_ARTIFACT_DIR)
        .map_err(|e| anyhow::anyhow!("{e}\nhint: run `make artifacts` first"))?;
    println!(
        "[1/3] registry: {} artifacts from {}",
        registry.all().len(),
        registry.dir().display()
    );
    let runtime = PjrtRuntime::cpu()?;
    println!(
        "      pjrt: platform={} devices={}",
        runtime.platform_name(),
        runtime.device_count()
    );

    // ---- Stage 2: cross-backend validation ---------------------------
    let backend = PjrtBackend::load(&runtime, &registry, "f4", 0)?;
    let meta = backend.meta().clone();
    let xcfg = JobConfig {
        maxcalls: meta.maxcalls,
        nb: meta.nb,
        nblocks: meta.nblocks,
        itmax: 4,
        ita: 3,
        skip: 0,
        tau_rel: 1e-14,
        seed: 999,
        ..Default::default()
    };
    let pjrt = run_driver(&backend, &xcfg)?;
    let f4 = by_name("f4", 5)?;
    let native = mcubes::coordinator::integrate_native(&*f4, &xcfg)?;
    let rel = ((pjrt.integral - native.integral) / native.integral).abs();
    println!(
        "[2/3] cross-backend check on {}: pjrt={:.12e} native={:.12e} rel diff={:.2e}",
        meta.name, pjrt.integral, native.integral, rel
    );
    assert!(rel < 1e-9, "backends disagree");

    // ---- Stage 3: batched service workload ----------------------------
    let suite: &[(&str, usize, usize)] = &[
        ("f2", 6, 1 << 15),
        ("f3", 3, 1 << 14),
        ("f3", 8, 1 << 16),
        ("f4", 5, 1 << 16),
        ("f5", 8, 1 << 15),
        ("f6", 6, 1 << 16),
        ("cosmo", 6, 1 << 14),
    ];
    let seeds_per_case = 4usize;
    let workers = std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(2)
        .clamp(1, 8);
    let mut svc = IntegrationService::new(workers);
    let mut id = 0u64;
    for (name, d, calls) in suite {
        for s in 0..seeds_per_case {
            svc.submit(JobRequest {
                id,
                integrand: name.to_string(),
                dim: *d,
                config: JobConfig {
                    maxcalls: *calls,
                    tau_rel: 1e-3,
                    itmax: 20,
                    ita: 12,
                    skip: 2,
                    seed: 7000 + id as u32 + s as u32,
                    ..Default::default()
                },
            });
            id += 1;
        }
    }
    println!(
        "[3/3] service: {} jobs ({} integrand cases x {} seeds) on {} workers",
        id,
        suite.len(),
        seeds_per_case,
        workers
    );
    let (results, metrics) = svc.drain()?;

    let mut t = Table::new(&[
        "integrand", "jobs", "converged", "max |rel err| vs truth", "median latency",
    ]);
    for (name, d, _) in suite {
        let f = by_name(name, *d)?;
        let truth = f.true_value().unwrap();
        let mut rels: Vec<f64> = Vec::new();
        let mut lats: Vec<f64> = Vec::new();
        let mut conv = 0;
        let key = name.to_string();
        for r in results.iter().filter(|r| r.integrand == key && r.dim == *d) {
            if let Ok(o) = &r.outcome {
                if o.calls_used > 0 {
                    rels.push(((o.integral - truth) / truth).abs());
                    lats.push(r.latency);
                    conv += usize::from(o.converged);
                }
            }
        }
        lats.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let max_rel = rels.iter().cloned().fold(0.0f64, f64::max);
        t.row(vec![
            format!("{name} (d={d})"),
            rels.len().to_string(),
            format!("{conv}/{}", rels.len()),
            format!("{max_rel:.2e}"),
            fmt_ms(lats.get(lats.len() / 2).copied().unwrap_or(0.0) * 1e3),
        ]);
    }
    println!("\n{}", t.render());
    println!(
        "throughput: {:.2} jobs/s | wall {} | p50 {} | p95 {} | failures {}",
        metrics.throughput,
        fmt_ms(metrics.wall_time * 1e3),
        fmt_ms(metrics.latency_p50 * 1e3),
        fmt_ms(metrics.latency_p95 * 1e3),
        metrics.failures
    );
    assert_eq!(metrics.failures, 0);
    println!("\nservice_demo OK — full stack (artifacts -> PJRT -> coordinator -> service) validated");
    Ok(())
}
