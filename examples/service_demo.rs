//! End-to-end demo of the persistent integration service: the durable
//! store, the spool-driven daemon, crash recovery, and the
//! content-addressed result cache.
//!
//! 1. Submits a small job suite to a fresh store and "kills" the
//!    daemon mid-run at a durable checkpoint — exactly the on-disk
//!    state a real `kill -9` leaves behind.
//! 2. Restarts a fresh daemon over the same store: every job resumes
//!    from its checkpoint and finishes. A control store that was never
//!    interrupted proves the recovery is **bitwise** — identical
//!    estimate, sigma, and chi2/dof.
//! 3. Re-submits the same work under new job ids: each is answered
//!    from the content-addressed cache with zero integrand
//!    evaluations.
//!
//! Run:
//!   cargo run --offline --release --example service_demo

use mcubes::coordinator::{read_result, submit_job, Daemon};
use mcubes::prelude::*;
use mcubes::util::table::Table;
use std::path::PathBuf;

/// The demo workload: (job id, integrand, dim, maxcalls).
const SUITE: &[(&str, &str, usize, usize)] = &[
    ("osc", "f3", 3, 1 << 16),
    ("gauss", "f4", 5, 1 << 16),
    ("expo", "f5", 8, 1 << 15),
];

fn job(id: &str, integrand: &str, dim: usize, maxcalls: usize) -> JobManifest {
    let cfg = JobConfig::default()
        .with_maxcalls(maxcalls)
        .with_tolerance(1e-12) // never converge early: fixed-length runs
        .with_plan(RunPlan::classic(10, 6, 1))
        .with_seed(42);
    JobManifest::new(id, integrand, dim, cfg).with_checkpoint_interval(2)
}

fn store_root(tag: &str) -> PathBuf {
    let p = std::env::temp_dir().join(format!("mcubes-service-demo-{}-{tag}", std::process::id()));
    let _ = std::fs::remove_dir_all(&p);
    p
}

fn main() -> Result<()> {
    // ---- Stage 1: submit, then die mid-run ---------------------------
    let victim_root = store_root("victim");
    for (id, integrand, dim, calls) in SUITE {
        submit_job(&victim_root, &job(id, integrand, *dim, *calls))?;
    }
    let mut victim = Daemon::open(&victim_root)?
        .with_threads(3)
        .with_crash_after_flushes(2); // "kill -9" after the 2nd flush
    let report = victim.run_pending()?;
    assert!(report.crashed);
    let spooled = victim.store().spool().pending()?.len();
    let checkpoints = victim.store().checkpoints().digests()?.len();
    drop(victim);
    println!(
        "[1/3] daemon killed mid-run: {spooled} submissions still spooled, \
         {checkpoints} durable checkpoint(s), no results published"
    );

    // An uninterrupted control run of the identical suite, different
    // thread count on purpose (results are thread-count invariant).
    let control_root = store_root("control");
    for (id, integrand, dim, calls) in SUITE {
        submit_job(&control_root, &job(id, integrand, *dim, *calls))?;
    }
    let report = Daemon::open(&control_root)?.with_threads(1).run_pending()?;
    assert_eq!(report.completed, SUITE.len());

    // ---- Stage 2: restart, resume, prove bitwise recovery ------------
    let mut revived = Daemon::open(&victim_root)?.with_threads(2);
    let report = revived.run_pending()?;
    println!(
        "[2/3] restarted daemon drained the store: {} completed, {} resumed from checkpoints",
        report.completed, report.resumed
    );
    assert_eq!(report.completed, SUITE.len());
    assert!(report.resumed >= 1, "at least the killed job must resume");

    let mut t = Table::new(&["job", "integrand", "I (resumed)", "sigma", "resumed@", "bitwise"]);
    for (id, integrand, dim, _) in SUITE {
        let resumed = read_result(&victim_root, id)?.expect("published result");
        let control = read_result(&control_root, id)?.expect("control result");
        let a = resumed.outcome.clone().expect("resumed run succeeds");
        let b = control.outcome.expect("control run succeeds");
        let bitwise = a.integral.to_bits() == b.integral.to_bits()
            && a.sigma.to_bits() == b.sigma.to_bits()
            && a.chi2_dof.to_bits() == b.chi2_dof.to_bits();
        assert!(bitwise, "{id}: crash/resume changed the numbers");
        t.row(vec![
            id.to_string(),
            format!("{integrand} (d={dim})"),
            format!("{:.12e}", a.integral),
            format!("{:.3e}", a.sigma),
            resumed.resumed_iteration.to_string(),
            "yes".to_string(),
        ]);
    }
    println!("{}", t.render());

    // ---- Stage 3: identical resubmission is a cache hit --------------
    let mut daemon = Daemon::open(&victim_root)?;
    for (id, integrand, dim, calls) in SUITE {
        // Same semantics, new job id and service metadata: the content
        // address ignores both.
        let resubmission = job(&format!("{id}-again"), integrand, *dim, *calls)
            .with_priority(5)
            .with_checkpoint_interval(7);
        submit_job(&victim_root, &resubmission)?;
    }
    let report = daemon.run_pending()?;
    assert_eq!(report.cache_hits, SUITE.len(), "every resubmission must hit");
    for (id, ..) in SUITE {
        let hit = read_result(&victim_root, &format!("{id}-again"))?.expect("cached result");
        assert!(hit.cached);
        let first = read_result(&victim_root, id)?.expect("original result");
        let (a, b) = (first.outcome.expect("ok"), hit.outcome.expect("ok"));
        assert_eq!(a.integral.to_bits(), b.integral.to_bits());
    }
    println!(
        "[3/3] resubmitted the whole suite under new ids: {} cache hits, zero re-integration",
        report.cache_hits
    );

    let _ = std::fs::remove_dir_all(&victim_root);
    let _ = std::fs::remove_dir_all(&control_root);
    println!("\nservice_demo OK — submit -> crash -> bitwise resume -> cache hit validated");
    Ok(())
}
