//! The paper's §6.1 use case: a stateful 6-D "cosmology-style"
//! integrand whose evaluation reads runtime-loaded interpolation
//! tables, run through the *full AOT stack* (Pallas artifact via PJRT,
//! tables passed as tensor inputs) and compared against the serial
//! VEGAS CPU baseline (the paper's CUBA comparison).
//!
//! Requires `make artifacts` and a `pjrt`-featured build. Run:
//!   cargo run --offline --release --example cosmology

use mcubes::baselines::vegas_serial_integrate;
use mcubes::integrands::Cosmo;
use mcubes::prelude::*;
use mcubes::runtime::DEFAULT_ARTIFACT_DIR;

fn main() -> Result<()> {
    // --- m-Cubes over the AOT artifact (tables flow in at runtime) ---
    let mut intg = Integrator::from_registry("cosmo", 6)?
        .backend(BackendSpec::Pjrt {
            artifacts_dir: DEFAULT_ARTIFACT_DIR.into(),
        })
        // maxcalls acts as the artifact's minimum budget on the PJRT
        // backend; 4 selects the smallest compiled cosmo artifact
        // (matching the pre-facade behavior of min_calls = 0).
        .maxcalls(4)
        .tolerance(1e-3)
        .plan(RunPlan::classic(15, 10, 2))
        .seed(7);
    let mcubes_out = intg.run().map_err(|e| {
        Error::Runtime(format!("{e}\nhint: run `make artifacts` first"))
    })?;

    // --- Serial VEGAS baseline (CUBA-style CPU implementation) ---
    // Same per-iteration budget the artifact actually used.
    let per_iter = (mcubes_out.calls_used / mcubes_out.iterations.max(1)).max(4);
    let f = mcubes::integrands::by_name("cosmo", 6)?;
    let serial = vegas_serial_integrate(&f, per_iter, 1e-3, 15, 7);

    // --- Reference by product quadrature over the same tables ---
    let truth = Cosmo::with_default_tables().quadrature_true_value(200_000);

    println!(
        "\n{:<22} {:>16} {:>12} {:>12} {:>10}",
        "method", "estimate", "errorest", "rel-true", "time(ms)"
    );
    for (name, i, s, t) in [
        (
            "m-Cubes (PJRT AOT)",
            mcubes_out.integral,
            mcubes_out.sigma,
            mcubes_out.total_time,
        ),
        (
            "serial VEGAS (CPU)",
            serial.integral,
            serial.sigma,
            serial.total_time,
        ),
    ] {
        println!(
            "{:<22} {:>16.8e} {:>12.3e} {:>12.3e} {:>10.1}",
            name,
            i,
            s,
            ((i - truth) / truth).abs(),
            t * 1e3
        );
    }
    println!("\nquadrature reference = {truth:.10e}");
    println!(
        "speedup (serial/mcubes total time): {:.2}x",
        serial.total_time / mcubes_out.total_time
    );
    assert!(mcubes_out.converged);
    Ok(())
}
