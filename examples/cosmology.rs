//! The paper's §6.1 use case: a stateful 6-D "cosmology-style"
//! integrand whose evaluation reads runtime-loaded interpolation
//! tables, run through the *full AOT stack* (Pallas artifact via PJRT,
//! tables passed as tensor inputs) and compared against the serial
//! VEGAS CPU baseline (the paper's CUBA comparison).
//!
//! Requires `make artifacts`. Run:
//!   cargo run --offline --release --example cosmology

use mcubes::baselines::vegas_serial_integrate;
use mcubes::coordinator::{run_driver, JobConfig, PjrtBackend};
use mcubes::integrands::{by_name, Cosmo};
use mcubes::runtime::{PjrtRuntime, Registry, DEFAULT_ARTIFACT_DIR};

fn main() -> anyhow::Result<()> {
    let registry = Registry::load(DEFAULT_ARTIFACT_DIR)
        .map_err(|e| anyhow::anyhow!("{e}\nhint: run `make artifacts` first"))?;
    let runtime = PjrtRuntime::cpu()?;
    println!("PJRT platform: {}", runtime.platform_name());

    // --- m-Cubes over the AOT artifact (tables flow in at runtime) ---
    let backend = PjrtBackend::load(&runtime, &registry, "cosmo", 0)?;
    let meta = backend.meta().clone();
    println!(
        "artifact {} (d={}, m={} cubes x p={} samples, {} tables x {} knots)",
        meta.name, meta.dim, meta.m, meta.p, meta.n_tables, meta.table_knots
    );
    let cfg = JobConfig {
        maxcalls: meta.maxcalls,
        nb: meta.nb,
        nblocks: meta.nblocks,
        tau_rel: 1e-3,
        itmax: 15,
        ita: 10,
        seed: 7,
        ..Default::default()
    };
    let mcubes_out = run_driver(&backend, &cfg)?;

    // --- Serial VEGAS baseline (CUBA-style CPU implementation) ---
    let f = by_name("cosmo", 6)?;
    let serial = vegas_serial_integrate(&*f, meta.maxcalls, 1e-3, 15, 7);

    // --- Reference by product quadrature over the same tables ---
    let truth = Cosmo::with_default_tables().quadrature_true_value(200_000);

    println!("\n{:<22} {:>16} {:>12} {:>12} {:>10}", "method", "estimate", "errorest", "rel-true", "time(ms)");
    for (name, i, s, t) in [
        (
            "m-Cubes (PJRT AOT)",
            mcubes_out.integral,
            mcubes_out.sigma,
            mcubes_out.total_time,
        ),
        ("serial VEGAS (CPU)", serial.integral, serial.sigma, serial.total_time),
    ] {
        println!(
            "{:<22} {:>16.8e} {:>12.3e} {:>12.3e} {:>10.1}",
            name,
            i,
            s,
            ((i - truth) / truth).abs(),
            t * 1e3
        );
    }
    println!("\nquadrature reference = {truth:.10e}");
    println!(
        "speedup (serial/mcubes total time): {:.2}x",
        serial.total_time / mcubes_out.total_time
    );
    assert!(mcubes_out.converged);
    Ok(())
}
