//! Fig.-1 style accuracy sweep (compact): run each suite integrand at
//! increasing digits of precision, multiple seeds, and report the
//! spread of achieved relative errors against the requested tolerance.
//! Uses the `Integrator` facade with escalation (budget x4 per level,
//! adapted grid carried across levels).
//!
//! Run: cargo run --offline --release --example precision_sweep [runs]

// Narrowing / float→int casts in this file are deliberate and
// audited by `cargo xtask lint` (MC001); see docs/invariants.md.
#![allow(clippy::cast_possible_truncation)]

use mcubes::prelude::*;
use mcubes::report::BoxStats;
use mcubes::util::table::Table;

fn main() -> Result<()> {
    let runs: usize = std::env::args()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .unwrap_or(10);
    let cases = [("f2", 6), ("f3", 3), ("f4", 5), ("f5", 8), ("f6", 6)];
    let taus = [1e-3, 2e-4, 4e-5];

    let mut table = Table::new(&[
        "integrand", "digits", "tau", "median rel", "q3 rel", "max rel", "met",
    ]);
    for (name, d) in cases {
        let f = mcubes::integrands::by_name(name, d)?;
        let truth = f.true_value().unwrap();
        for tau in taus {
            let mut achieved = Vec::with_capacity(runs);
            let mut conv = 0usize;
            for r in 0..runs {
                let out = Integrator::new(f.clone())
                    .maxcalls(1 << 14)
                    .tolerance(tau)
                    .plan(RunPlan::classic(20, 12, 2))
                    .seed(9000 + r as u32)
                    .escalate(6, 4)
                    .run()?;
                if out.converged {
                    conv += 1;
                    achieved.push(((out.integral - truth) / truth).abs());
                }
            }
            let b = BoxStats::from_samples(&achieved);
            table.row(vec![
                format!("{name} d={d}"),
                format!("{:.1}", -tau.log10()),
                format!("{tau:.0e}"),
                format!("{:.2e}", b.median),
                format!("{:.2e}", b.q3),
                format!("{:.2e}", b.max),
                format!("{conv}/{runs}"),
            ]);
        }
    }
    println!("{}", table.render());
    println!("(median achieved error should sit at or below the requested tau)");
    Ok(())
}
