//! Quickstart for the `Integrator` facade:
//!
//!  1. a registry integrand (the paper's f4, a sharp 5-D Gaussian),
//!  2. a closure integrand over non-uniform per-axis bounds,
//!  3. a grid warm-start that skips the importance-grid warm-up,
//!  4. a pull-based `Session`: step, suspend to a checkpoint, resume.
//!
//! The seed-era free functions (`integrate_native`, `run_driver`, ...)
//! have been removed (see the migration table in the `api` module
//! docs); the flat `max_iterations`/`adjust_iterations`/
//! `skip_iterations` builder knobs remain as `#[deprecated]` shims
//! over `RunPlan` and the same session core — new code should look
//! like this file.
//!
//! Run: cargo run --offline --release --example quickstart

use mcubes::prelude::*;

fn main() -> Result<()> {
    // --- 1. Registry integrand through the builder -------------------
    // The paper's f4 (eq. 4): exp(-625 * sum (x_i - 1/2)^2) over [0,1]^5.
    let mut intg = Integrator::from_registry("f4", 5)?
        .maxcalls(1 << 17) // evaluations per iteration
        .tolerance(1e-3) // requested relative error (3 digits)
        .plan(RunPlan::classic(15, 10, 2)); // itmax 15, 10 adjusting, 2 discarded
    let out = intg.run()?;

    println!("m-Cubes quickstart — integrand f4 (5-D Gaussian)");
    println!("  integral   = {:.10e}", out.integral);
    println!("  sigma      = {:.3e}", out.sigma);
    println!("  rel error  = {:.3e} (requested 1e-3)", out.rel_err);
    println!("  chi2/dof   = {:.3}", out.chi2_dof);
    println!(
        "  iterations = {} (converged: {})",
        out.iterations, out.converged
    );
    println!("  calls used = {}", out.calls_used);
    println!("  time       = {:.1} ms", out.total_time * 1e3);

    let f = mcubes::integrands::by_name("f4", 5)?;
    let truth = f.true_value().unwrap();
    println!("  true value = {:.10e}", truth);
    println!(
        "  true rel   = {:.3e}",
        ((out.integral - truth) / truth).abs()
    );
    assert!(out.converged, "did not converge");

    // --- 2. Closure integrand over per-axis bounds -------------------
    // ∫∫ x·y over [0,2]×[1,3] = 2 · 4 = 8, no registry entry needed.
    let bounds = Bounds::per_axis(&[(0.0, 2.0), (1.0, 3.0)])?;
    let xy = Integrator::from_fn(2, bounds, |x| x[0] * x[1])?
        .maxcalls(1 << 14)
        .tolerance(1e-3)
        .run()?;
    println!("\nclosure ∫ x·y over [0,2]×[1,3]:");
    println!(
        "  integral   = {:.6} (exact 8), rel-true {:.2e}",
        xy.integral,
        ((xy.integral - 8.0) / 8.0).abs()
    );

    // --- 3. Warm-start: reuse the adapted grid -----------------------
    let grid = intg.export_grid().expect("grid after run");
    let warm = Integrator::from_registry("f4", 5)?
        .maxcalls(1 << 17)
        .tolerance(1e-3)
        .seed(43) // fresh samples, same adapted grid
        .warm_start(grid)
        .plan(RunPlan::classic(15, 0, 0)) // the grid is already adapted
        .run()?;
    println!("\nwarm-started rerun:");
    println!(
        "  iterations = {} (cold start took {})",
        warm.iterations, out.iterations
    );
    assert!(warm.converged);

    // --- 4. Pull-based session: step, suspend, resume ----------------
    // The same run, inside out: step() advances exactly one iteration
    // and hands back a typed snapshot. suspend() exports a Checkpoint
    // (grid + estimator sums + RNG cursor) that resume() restores
    // bit-identically — pause an expensive integral, persist it, and
    // pick it up later (or elsewhere).
    let builder = || -> Result<Integrator> {
        Ok(Integrator::from_registry("f4", 5)?
            .maxcalls(1 << 15)
            .tolerance(1e-3)
            .plan(RunPlan::warmup_then_final(5, 1 << 12, 10))
            .seed(7))
    };
    let mut session = builder()?.session()?;
    println!("\nsession (warm-up at 2^12 calls, then frozen grid at 2^15):");
    let mut checkpoint = None;
    while let Some(it) = session.step()? {
        println!(
            "  it {:>2} [{:>13}] rel {:.2e}",
            it.index, it.stage_label, it.rel_err
        );
        if it.index == 2 {
            checkpoint = Some(session.suspend()); // e.g. save to disk here
        }
    }
    let full = session.finish()?;

    // Resume the mid-run checkpoint; the continuation reproduces the
    // uninterrupted run bit for bit.
    let resumed = builder()?
        .resume_session(checkpoint.as_ref().expect("suspended at it 2"))?
        .finish()?;
    println!(
        "  finish: I = {:.10e} ({:?}); resumed-from-checkpoint I matches bitwise: {}",
        full.output.integral,
        full.stop,
        resumed.output.integral.to_bits() == full.output.integral.to_bits()
    );
    assert_eq!(
        resumed.output.integral.to_bits(),
        full.output.integral.to_bits()
    );
    Ok(())
}
