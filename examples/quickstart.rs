//! Quickstart: integrate a sharp 5-D Gaussian with m-Cubes (native
//! engine) and compare against the analytic value.
//!
//! Run: cargo run --offline --release --example quickstart

use mcubes::coordinator::{integrate_native, JobConfig};
use mcubes::integrands::by_name;

fn main() -> anyhow::Result<()> {
    // The paper's f4 (eq. 4): exp(-625 * sum (x_i - 1/2)^2) over [0,1]^5.
    let f = by_name("f4", 5)?;

    let cfg = JobConfig {
        maxcalls: 1 << 17, // evaluations per iteration
        tau_rel: 1e-3,     // requested relative error (3 digits)
        itmax: 15,
        ita: 10, // iterations with importance-grid adjustment
        ..Default::default()
    };

    let out = integrate_native(&*f, &cfg)?;

    println!("m-Cubes quickstart — integrand f4 (5-D Gaussian)");
    println!("  integral   = {:.10e}", out.integral);
    println!("  sigma      = {:.3e}", out.sigma);
    println!("  rel error  = {:.3e} (requested {:.0e})", out.rel_err, cfg.tau_rel);
    println!("  chi2/dof   = {:.3}", out.chi2_dof);
    println!("  iterations = {} (converged: {})", out.iterations, out.converged);
    println!("  calls used = {}", out.calls_used);
    println!("  time       = {:.1} ms", out.total_time * 1e3);

    let truth = f.true_value().unwrap();
    println!("  true value = {:.10e}", truth);
    println!("  true rel   = {:.3e}", ((out.integral - truth) / truth).abs());

    assert!(out.converged, "did not converge");
    Ok(())
}
