//! Quickstart for the `Integrator` facade:
//!
//!  1. a registry integrand (the paper's f4, a sharp 5-D Gaussian),
//!  2. a closure integrand over non-uniform per-axis bounds,
//!  3. a grid warm-start that skips the importance-grid warm-up.
//!
//! The seed-era free functions (`integrate_native`, `run_driver`, ...)
//! still exist but are `#[deprecated]` shims over the same core — new
//! code should look like this file.
//!
//! Run: cargo run --offline --release --example quickstart

use mcubes::prelude::*;

fn main() -> Result<()> {
    // --- 1. Registry integrand through the builder -------------------
    // The paper's f4 (eq. 4): exp(-625 * sum (x_i - 1/2)^2) over [0,1]^5.
    let mut intg = Integrator::from_registry("f4", 5)?
        .maxcalls(1 << 17) // evaluations per iteration
        .tolerance(1e-3) // requested relative error (3 digits)
        .max_iterations(15)
        .adjust_iterations(10); // iterations with grid adjustment
    let out = intg.run()?;

    println!("m-Cubes quickstart — integrand f4 (5-D Gaussian)");
    println!("  integral   = {:.10e}", out.integral);
    println!("  sigma      = {:.3e}", out.sigma);
    println!("  rel error  = {:.3e} (requested 1e-3)", out.rel_err);
    println!("  chi2/dof   = {:.3}", out.chi2_dof);
    println!(
        "  iterations = {} (converged: {})",
        out.iterations, out.converged
    );
    println!("  calls used = {}", out.calls_used);
    println!("  time       = {:.1} ms", out.total_time * 1e3);

    let f = mcubes::integrands::by_name("f4", 5)?;
    let truth = f.true_value().unwrap();
    println!("  true value = {:.10e}", truth);
    println!(
        "  true rel   = {:.3e}",
        ((out.integral - truth) / truth).abs()
    );
    assert!(out.converged, "did not converge");

    // --- 2. Closure integrand over per-axis bounds -------------------
    // ∫∫ x·y over [0,2]×[1,3] = 2 · 4 = 8, no registry entry needed.
    let bounds = Bounds::per_axis(&[(0.0, 2.0), (1.0, 3.0)])?;
    let xy = Integrator::from_fn(2, bounds, |x| x[0] * x[1])?
        .maxcalls(1 << 14)
        .tolerance(1e-3)
        .run()?;
    println!("\nclosure ∫ x·y over [0,2]×[1,3]:");
    println!(
        "  integral   = {:.6} (exact 8), rel-true {:.2e}",
        xy.integral,
        ((xy.integral - 8.0) / 8.0).abs()
    );

    // --- 3. Warm-start: reuse the adapted grid -----------------------
    let grid = intg.export_grid().expect("grid after run");
    let warm = Integrator::from_registry("f4", 5)?
        .maxcalls(1 << 17)
        .tolerance(1e-3)
        .seed(43) // fresh samples, same adapted grid
        .warm_start(grid)
        .adjust_iterations(0) // the grid is already adapted
        .skip_iterations(0)
        .run()?;
    println!("\nwarm-started rerun:");
    println!(
        "  iterations = {} (cold start took {})",
        warm.iterations, out.iterations
    );
    assert!(warm.converged);
    Ok(())
}
